//! The Möbius Join dynamic program (paper §4.2, Algorithms 1 and 2).
//!
//! Starting from positive-relationship statistics (computed by table joins,
//! `crate::db::JoinCounter`), the algorithm extends them to *negative*
//! relationships without ever materializing entity cross products, by
//! applying the ct-algebra identity of Proposition 1:
//!
//! ```text
//! ct(Vars ∪ 1Atts(R) | R = F)
//!   = ct(Vars | R = *) × ct(X1) × … × ct(Xl)  −  ct(Vars ∪ 1Atts(R) | R = T)
//! ```
//!
//! level-by-level over the relationship-chain lattice.
//!
//! ## Packed tiers end to end
//!
//! Every table the dynamic program touches stays on a packed integer-key
//! store as long as its layout fits 128 bits: positive join tables and
//! entity tables are built packed directly (`crate::db`), and each
//! ct-algebra operator runs a one-word (`u64`) or two-word (`u128`) kernel
//! as its operands require. [`MjMetrics::reference_fallbacks`] counts the
//! operator calls that had to route through the row-major reference path
//! instead — zero for every benchmark schema in this repo, including the
//! 65–128-bit joint layouts of the hepatitis/imdb scale (asserted by
//! `rust/tests/wide_tier.rs`).
//!
//! ## Parallel levels
//!
//! Chains within one lattice level are independent given the previous
//! levels' tables: each length-`l` chain reads only length-`l−1` tables
//! (Algorithm 2 line 13) and the entity tables. [`MobiusJoin::workers`]
//! therefore fans the per-level chain loop out over a scoped worker pool.
//! Results are inserted in lattice order and every chain's computation is
//! deterministic, so the output is **identical for any worker count**
//! (asserted by `rust/tests/integration_mj.rs`).

pub mod engine;
pub mod metrics;
pub mod postcount;

pub use engine::{CtEngine, CtSink, NativeEngine};
pub use postcount::PostCounter;
pub use metrics::{CtOp, LevelStats, MjMetrics};

use crate::ct::CtTable;
use crate::db::{Database, JoinCounter};
use crate::lattice::{components, Lattice};
use crate::schema::{FoVarId, RelId, VarId, NA};
use crate::util::fxhash::FxHashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Output of a Möbius Join run: one contingency table per relationship
/// chain, the per-FO-variable entity tables, the joint table for the whole
/// database, and run metrics.
#[derive(Debug)]
pub struct MjResult {
    pub lattice: Lattice,
    /// `ct(1Atts(X))` per FO variable.
    pub entity_cts: FxHashMap<FoVarId, CtTable>,
    /// Full contingency table per chain (keyed by sorted rel set).
    pub tables: FxHashMap<Vec<RelId>, CtTable>,
    /// Joint table over all variables in the database. `None` when the run
    /// was capped below the full chain length (§8 option).
    pub joint: Option<CtTable>,
    pub metrics: MjMetrics,
    /// Sorted VarIds of all relationship indicator variables.
    indicator_ids: Vec<VarId>,
}

impl MjResult {
    /// Reassemble a result from already-computed parts — the read side of
    /// the persistence layer (`crate::store::CtStore::load_mj_result`), so
    /// the statistical apps can score from a warm store without re-running
    /// the join. Metrics are zeroed: no join was executed.
    pub fn assemble(
        schema: &crate::schema::Schema,
        entity_cts: FxHashMap<FoVarId, CtTable>,
        tables: FxHashMap<Vec<RelId>, CtTable>,
        joint: Option<CtTable>,
    ) -> MjResult {
        let lattice = Lattice::build(schema, None);
        let mut indicator_ids: Vec<VarId> =
            (0..schema.num_rel_vars()).map(|r| schema.rel_ind_var(r)).collect();
        indicator_ids.sort_unstable();
        MjResult {
            lattice,
            entity_cts,
            tables,
            joint,
            metrics: MjMetrics::default(),
            indicator_ids,
        }
    }

    /// The joint contingency table (panics if the run was depth-capped).
    pub fn joint_ct(&self) -> &CtTable {
        self.joint.as_ref().expect("joint ct unavailable: run was depth-capped")
    }

    /// "Link Analysis On" statistic count: rows of the joint table.
    pub fn num_statistics(&self) -> usize {
        self.joint_ct().len()
    }

    /// The "Link Analysis Off" table: the joint table restricted to all
    /// relationships true (indicator columns retained, all = T).
    pub fn link_off(&self) -> CtTable {
        let conds: Vec<(VarId, u16)> = self
            .indicator_ids
            .iter()
            .copied()
            .filter(|v| self.joint_ct().col_of(*v).is_some())
            .map(|v| (v, 1u16))
            .collect();
        self.joint_ct().select(&conds)
    }

    /// Number of sufficient statistics that involve at least one negative
    /// relationship (the paper's "#extra statistics", Table 4, and the `r`
    /// of Proposition 2).
    pub fn num_extra_statistics(&self) -> usize {
        self.num_statistics() - self.link_off().len()
    }
}

/// One chain's worth of work: the finished table plus locally-collected
/// metrics (merged into the global record in lattice order, so the merge is
/// deterministic regardless of worker scheduling).
struct ChainOut {
    table: CtTable,
    metrics: MjMetrics,
}

/// Configuration + entry point for the Möbius Join.
pub struct MobiusJoin<'a> {
    db: &'a Database,
    engine: &'a dyn CtEngine,
    max_chain_len: Option<usize>,
    workers: usize,
    sink: Option<&'a dyn engine::CtSink>,
    progress: bool,
}

impl<'a> MobiusJoin<'a> {
    /// Möbius Join with the native (pure-rust) engine.
    pub fn new(db: &'a Database) -> Self {
        MobiusJoin {
            db,
            engine: &NativeEngine,
            max_chain_len: None,
            workers: 1,
            sink: None,
            progress: false,
        }
    }

    /// Möbius Join with a custom execution engine.
    pub fn with_engine(db: &'a Database, engine: &'a dyn CtEngine) -> Self {
        MobiusJoin { db, engine, max_chain_len: None, workers: 1, sink: None, progress: false }
    }

    /// Attach a write-on-complete sink: every finished table (entity,
    /// per-chain positive, per-chain complete, joint) is handed to it as
    /// the dynamic program produces it. Positive-table callbacks may fire
    /// from worker threads when `workers > 1`.
    pub fn sink(mut self, s: &'a dyn engine::CtSink) -> Self {
        self.sink = Some(s);
        self
    }

    /// Cap the chain length (paper §8: compute the lattice only up to a
    /// prespecified level).
    pub fn max_chain_len(mut self, len: usize) -> Self {
        self.max_chain_len = Some(len);
        self
    }

    /// Evaluate each lattice level's chains on up to `n` worker threads
    /// (1 = serial, the default). Output is identical for any `n`.
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n.max(1);
        self
    }

    /// Print live per-level build progress to stderr (`--progress`): one
    /// line per finished chain with chains done/total, rows and bytes
    /// emitted so far, elapsed time, and an ETA from completed-chain
    /// throughput. Per-level totals land in [`MjMetrics::levels`] whether
    /// or not this is on.
    pub fn progress(mut self, on: bool) -> Self {
        self.progress = on;
        self
    }

    /// Run Algorithm 2.
    pub fn run(&self) -> MjResult {
        let t0 = Instant::now();
        // Delta of the process-wide reference-fallback counter attributes
        // row-major routings to this run (schemas whose tables stay within
        // 128-bit layouts never leave the packed kernels and record 0).
        let fallbacks0 = crate::ct::reference::reference_op_fallbacks();
        let schema = &self.db.schema;
        let lattice = Lattice::build(schema, self.max_chain_len);
        let mut metrics = MjMetrics::default();

        // --- Initialization: entity ct-tables (Algorithm 2 lines 1-3).
        let tp = Instant::now();
        let mut entity_cts: FxHashMap<FoVarId, CtTable> = FxHashMap::default();
        for fo in 0..schema.fo_vars.len() {
            let ct = self.db.ct_entity(fo);
            if let Some(s) = self.sink {
                s.on_entity(fo, &ct);
            }
            entity_cts.insert(fo, ct);
        }
        metrics.positive += tp.elapsed();

        // --- Levels 1..m: chains within a level are independent given the
        // previous level's tables, so each level fans out over the worker
        // pool (lines 4-8 for level 1, lines 9-23 above).
        let mut tables: FxHashMap<Vec<RelId>, CtTable> = FxHashMap::default();
        for level in 1..=lattice.max_level() {
            let chains: Vec<Vec<RelId>> = lattice.level(level).cloned().collect();
            let total_chains = chains.len();
            let level_t0 = Instant::now();
            // Done-counter + emitted totals, updated (and, with
            // `--progress`, printed) under one lock so the progress lines
            // are strictly monotone even when workers finish concurrently.
            let done = Mutex::new((0usize, 0u64, 0u64)); // (chains, rows, bytes)
            let outs = parallel_map(self.workers, chains.len(), |i| {
                let out = self.run_chain(&chains[i], &tables, &entity_cts);
                let mut d = done.lock().unwrap();
                d.0 += 1;
                d.1 += out.table.len() as u64;
                d.2 += out.table.mem_bytes() as u64;
                if self.progress {
                    let elapsed = level_t0.elapsed();
                    // ETA from completed-chain throughput; chains within a
                    // level vary in size, so this is a guide, not a bound.
                    let eta = elapsed.mul_f64((total_chains - d.0) as f64 / d.0 as f64);
                    eprintln!(
                        "[mobius] level {level}: {}/{total_chains} chains  rows {}  bytes {}  \
                         elapsed {}  eta {}",
                        d.0,
                        d.1,
                        d.2,
                        crate::util::format_duration(elapsed),
                        crate::util::format_duration(eta),
                    );
                }
                drop(d);
                out
            });
            for (chain, out) in chains.into_iter().zip(outs) {
                metrics.merge(&out.metrics);
                if let Some(s) = self.sink {
                    s.on_chain(&chain, &out.table);
                }
                tables.insert(chain, out.table);
            }
            let (chains_done, rows, bytes) = done.into_inner().unwrap();
            let stats = metrics::LevelStats {
                level,
                chains: chains_done as u64,
                rows,
                bytes,
                elapsed: level_t0.elapsed(),
            };
            if let Some(s) = self.sink {
                s.on_level(&stats);
            }
            metrics.levels.push(stats);
        }

        // --- Joint table for the entire database (line 24), factorizing
        // over connected components and populations outside all
        // relationships.
        let joint = if self.max_chain_len.is_none() || lattice.max_level() == schema.num_rel_vars()
        {
            let j = self.build_joint(&tables, &entity_cts, &mut metrics);
            if let Some(s) = self.sink {
                s.on_joint(&j);
            }
            Some(j)
        } else {
            None
        };

        metrics.total = t0.elapsed();
        metrics.reference_fallbacks =
            crate::ct::reference::reference_op_fallbacks().saturating_sub(fallbacks0);
        let mut indicator_ids: Vec<VarId> =
            (0..schema.num_rel_vars()).map(|r| schema.rel_ind_var(r)).collect();
        indicator_ids.sort_unstable();
        MjResult { lattice, entity_cts, tables, joint, metrics, indicator_ids }
    }

    /// Compute one chain's full table (any level). Level 1 (singleton
    /// chains, Algorithm 2 lines 4-8) builds `ct_*` from the two entity
    /// tables; deeper levels (lines 10-21) pivot each relationship in turn
    /// against tables from the previous level.
    fn run_chain(
        &self,
        chain: &[RelId],
        tables: &FxHashMap<Vec<RelId>, CtTable>,
        entity_cts: &FxHashMap<FoVarId, CtTable>,
    ) -> ChainOut {
        let schema = &self.db.schema;
        let mut m = MjMetrics::default();
        if let [r] = chain {
            let rel = &schema.relationships[*r];
            // ct_* := ct(X) × ct(Y) — both FO variables of the relationship.
            let sw = Instant::now();
            let tx = Instant::now();
            let ct_star = self
                .engine
                .cross(&entity_cts[&rel.fo_vars[0]], &entity_cts[&rel.fo_vars[1]]);
            m.record(CtOp::Cross, tx.elapsed());
            m.main_loop += sw.elapsed();

            // ct_T := ct(1Atts(R), 2Atts(R) | R = T) via join (line 6).
            let tp = Instant::now();
            let ct_t = JoinCounter::new(self.db).positive_ct(chain);
            m.positive += tp.elapsed();
            if let Some(s) = self.sink {
                s.on_positive(chain, &ct_t);
            }

            let table = self.pivot(&ct_t, &ct_star, *r, &mut m);
            return ChainOut { table, metrics: m };
        }
        // line 11: all-true table via join.
        let tp = Instant::now();
        let mut current = JoinCounter::new(self.db).positive_ct(chain);
        m.positive += tp.elapsed();
        if let Some(s) = self.sink {
            s.on_positive(chain, &current);
        }
        // lines 12-21: pivot each relationship in turn.
        for i in 0..chain.len() {
            let ct_star = self.ct_star_for(chain, i, tables, entity_cts, &mut m);
            current = self.pivot(&current, &ct_star, chain[i], &mut m);
        }
        ChainOut { table: current, metrics: m }
    }

    /// Algorithm 1: the Pivot function. `ct_t` is the conditional table with
    /// the pivot true (and its 2Atts as columns); `ct_star` is the table
    /// with the pivot unspecified (no pivot columns). Returns the complete
    /// table with the pivot indicator and its 2Atts as columns.
    fn pivot(
        &self,
        ct_t: &CtTable,
        ct_star: &CtTable,
        pivot_rel: RelId,
        metrics: &mut MjMetrics,
    ) -> CtTable {
        let schema = &self.db.schema;
        let sw = Instant::now();

        // line 1: ct_F := ct_* − π_Vars ct_T  (Equation 1).
        let t = Instant::now();
        let proj_t = self.engine.project(ct_t, &ct_star.vars);
        metrics.record(CtOp::Project, t.elapsed());
        let t = Instant::now();
        let ct_f = self
            .engine
            .subtract(ct_star, &proj_t)
            .unwrap_or_else(|e| panic!("pivot invariant violated for rel {pivot_rel}: {e}"));
        metrics.record(CtOp::Subtract, t.elapsed());

        // lines 2-3: extend with the pivot indicator and n/a 2Atts.
        let ind = schema.rel_ind_var(pivot_rel);
        let two_atts = schema.two_atts_of_rel(pivot_rel);
        let t = Instant::now();
        let mut consts_f: Vec<(VarId, u16)> = vec![(ind, 0)];
        consts_f.extend(two_atts.iter().map(|&v| (v, NA)));
        let ct_f_plus = ct_f.extend_const(&consts_f);
        let ct_t_plus = ct_t.extend_const(&[(ind, 1)]);
        metrics.record(CtOp::Extend, t.elapsed());

        // line 4: union of the two disjoint branches.
        let t = Instant::now();
        let out = ct_f_plus.union_disjoint(&ct_t_plus);
        metrics.record(CtOp::Union, t.elapsed());

        metrics.pivot += sw.elapsed();
        out
    }

    /// Build `ct_*` for pivot position `i` of `chain` (Algorithm 2 lines
    /// 13-19): take the table of `chain − {chain[i]}` (factorized over its
    /// connected components), condition the later relationships to true,
    /// and cross in entity tables for FO variables only the pivot touches.
    fn ct_star_for(
        &self,
        chain: &[RelId],
        i: usize,
        tables: &FxHashMap<Vec<RelId>, CtTable>,
        entity_cts: &FxHashMap<FoVarId, CtTable>,
        metrics: &mut MjMetrics,
    ) -> CtTable {
        let schema = &self.db.schema;
        let sw = Instant::now();
        let pivot_rel = chain[i];
        let rest: Vec<RelId> = chain.iter().copied().filter(|&r| r != pivot_rel).collect();
        debug_assert!(!rest.is_empty());
        // Later relationships (pivot order is ascending rel id) must be
        // conditioned to true.
        let later: Vec<RelId> = chain[i + 1..].to_vec();

        let mut acc: Option<CtTable> = None;
        for comp in components(schema, &rest) {
            let table = tables.get(&comp).expect("shorter chain table missing");
            let conds: Vec<(VarId, u16)> = comp
                .iter()
                .copied()
                .filter(|r| later.contains(r))
                .map(|r| (schema.rel_ind_var(r), 1))
                .collect();
            let part = if conds.is_empty() {
                table.clone()
            } else {
                let t = Instant::now();
                let c = self.engine.condition(table, &conds);
                metrics.record(CtOp::Condition, t.elapsed());
                c
            };
            acc = Some(match acc {
                None => part,
                Some(a) => {
                    let t = Instant::now();
                    let x = self.engine.cross(&a, &part);
                    metrics.record(CtOp::Cross, t.elapsed());
                    x
                }
            });
        }
        let mut acc = acc.expect("rest is non-empty");

        // Cross in ct(X) for FO variables of the pivot not covered by rest
        // (the `× ct(X1) × … × ct(Xl)` term of Equation 1).
        let rest_fos = schema.fo_vars_of_rels(&rest);
        for &fo in &schema.relationships[pivot_rel].fo_vars {
            if !rest_fos.contains(&fo) {
                let t = Instant::now();
                acc = self.engine.cross(&acc, &entity_cts[&fo]);
                metrics.record(CtOp::Cross, t.elapsed());
            }
        }
        metrics.main_loop += sw.elapsed();
        acc
    }

    /// Joint table over the whole database: cross product of the maximal
    /// connected components' tables, plus entity tables of FO variables
    /// outside every relationship.
    fn build_joint(
        &self,
        tables: &FxHashMap<Vec<RelId>, CtTable>,
        entity_cts: &FxHashMap<FoVarId, CtTable>,
        metrics: &mut MjMetrics,
    ) -> CtTable {
        let schema = &self.db.schema;
        let all: Vec<RelId> = (0..schema.num_rel_vars()).collect();
        let mut acc: Option<CtTable> = None;
        let cross_acc = |acc: Option<CtTable>, part: CtTable, m: &mut MjMetrics| match acc {
            None => Some(part),
            Some(a) => {
                let t = Instant::now();
                let x = self.engine.cross(&a, &part);
                m.record(CtOp::Cross, t.elapsed());
                Some(x)
            }
        };
        for comp in components(schema, &all) {
            let part = tables.get(&comp).expect("component table missing").clone();
            acc = cross_acc(acc, part, metrics);
        }
        // Populations/FO variables untouched by any relationship.
        let covered = schema.fo_vars_of_rels(&all);
        for fo in 0..schema.fo_vars.len() {
            if !covered.contains(&fo) {
                acc = cross_acc(acc, entity_cts[&fo].clone(), metrics);
            }
        }
        acc.unwrap_or_else(|| CtTable::scalar(1))
    }
}

/// Run `f(0..n)` over up to `workers` scoped threads, returning results in
/// index order. Work-steals via an atomic cursor; falls back to a plain
/// serial loop for one worker or one item. A panicking job propagates when
/// the scope joins, matching serial behaviour.
fn parallel_map<T, F>(workers: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if workers <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..workers.min(n) {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let v = f(i);
                *slots[i].lock().unwrap() = Some(v);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker result missing"))
        .collect()
}

// The indicator-id stash needs to be a real field; declared here to keep the
// struct definition above focused.
#[doc(hidden)]
impl MjResult {
    pub fn indicator_vars(&self) -> &[VarId] {
        &self.indicator_ids
    }
}

#[cfg(test)]
mod tests;
