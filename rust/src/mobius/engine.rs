//! Pluggable execution engine for the bulk ct-algebra operations.
//!
//! The Möbius Join routes its heavy operators through a [`CtEngine`] so the
//! same dynamic program can run on the pure-rust implementations or on the
//! AOT-compiled XLA kernels (`crate::runtime::XlaEngine`), and so the two
//! can be benchmarked against each other (`benches/bench_ablation.rs`).

use crate::ct::{CtTable, SubtractError};
use crate::schema::{FoVarId, RelId, VarId};

/// The operations the Möbius Join delegates. Default methods call the
/// native `CtTable` implementations; engines override whichever ops they
/// accelerate and must be bit-identical to the native semantics.
///
/// `Sync` is a supertrait: the parallel level loop shares one engine
/// reference across its worker threads.
pub trait CtEngine: Sync {
    /// π projection with count summation (GROUP BY).
    fn project(&self, ct: &CtTable, keep: &[VarId]) -> CtTable {
        ct.project(keep)
    }

    /// Count subtraction (minuend ⊇ subtrahend).
    fn subtract(&self, a: &CtTable, b: &CtTable) -> Result<CtTable, SubtractError> {
        a.subtract(b)
    }

    /// Cross product with count multiplication.
    fn cross(&self, a: &CtTable, b: &CtTable) -> CtTable {
        a.cross(b)
    }

    /// χ conditioning.
    fn condition(&self, ct: &CtTable, cond: &[(VarId, u16)]) -> CtTable {
        ct.condition(cond)
    }

    /// Engine name for metrics/reporting.
    fn name(&self) -> &'static str;
}

/// Write-on-complete hooks for the Möbius Join: the dynamic program calls
/// these the moment each table is final, so a sink (e.g. the persistence
/// layer, `crate::store::StoreSink`) can stream results out without a
/// separate export pass over `MjResult`.
///
/// `Sync` because chain-level callbacks (`on_positive`) fire from the
/// parallel level loop's worker threads. All default implementations are
/// no-ops; tables are borrowed — clone if you need to keep them.
pub trait CtSink: Sync {
    /// An entity table `ct(1Atts(X))` is final (initialization phase).
    fn on_entity(&self, _fo: FoVarId, _ct: &CtTable) {}

    /// A chain's all-true ("positive") table is final — the join-counter
    /// output before any pivot, with no indicator columns.
    fn on_positive(&self, _chain: &[RelId], _ct: &CtTable) {}

    /// A chain's complete table (indicators + n/a rows) is final.
    fn on_chain(&self, _chain: &[RelId], _ct: &CtTable) {}

    /// A whole lattice level finished: its aggregated build telemetry is
    /// final (chains, rows, bytes, wall time). Fires from the driving
    /// thread after every level, before the next level starts.
    fn on_level(&self, _stats: &super::metrics::LevelStats) {}

    /// The joint table over the whole database is final.
    fn on_joint(&self, _ct: &CtTable) {}
}

/// Pure-rust reference engine.
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeEngine;

impl CtEngine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_engine_delegates() {
        let e = NativeEngine;
        let a = CtTable::from_raw(vec![0, 1], vec![0, 0, 1, 1], vec![3, 4]);
        assert_eq!(e.project(&a, &[0]), a.project(&[0]));
        assert_eq!(e.cross(&a.project(&[0]), &CtTable::scalar(2)), a.project(&[0]).scale(2));
        assert_eq!(e.name(), "native");
    }
}
