//! PCG-XSL-RR 128/64: a small, fast, statistically solid PRNG.
//!
//! Deterministic seeding keeps every generator, test, and benchmark
//! reproducible run-to-run (the synthetic benchmark databases must be
//! byte-identical across processes so MJ and CP baselines see the same data).

/// PCG64 pseudo-random number generator (O'Neill 2014, XSL-RR variant).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Convenience constructor with the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift with rejection.
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0) is undefined");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let low = m as u64;
            if low >= bound.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[0, bound)`.
    pub fn index(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Sample an index from an (unnormalized) weight vector.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted() needs positive total weight");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Zipf-like skewed category draw over `n` categories with exponent `s`.
    /// Used by the dataset generators to get realistic value skew.
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n > 0);
        // Inverse-CDF over precomputable harmonic weights would need alloc;
        // for the small n used here a linear pass is fine.
        let mut total = 0.0;
        for k in 1..=n {
            total += 1.0 / (k as f64).powf(s);
        }
        let mut x = self.f64() * total;
        for k in 1..=n {
            x -= 1.0 / (k as f64).powf(s);
            if x < 0.0 {
                return k - 1;
            }
        }
        n - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Floyd's algorithm.
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.index(j + 1);
            if chosen.insert(t) {
                out.push(t);
            } else {
                chosen.insert(j);
                out.push(j);
            }
        }
        self.shuffle(&mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Pcg64::seeded(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Pcg64::seeded(9);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn weighted_respects_weights() {
        let mut r = Pcg64::seeded(3);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..4000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 2);
    }

    #[test]
    fn zipf_skews_to_head() {
        let mut r = Pcg64::seeded(4);
        let mut counts = [0usize; 5];
        for _ in 0..5000 {
            counts[r.zipf(5, 1.2)] += 1;
        }
        assert!(counts[0] > counts[4]);
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg64::seeded(5);
        for _ in 0..50 {
            let s = r.sample_indices(20, 8);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), 8);
            assert!(s.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seeded(6);
        let mut v: Vec<u32> = (0..30).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..30).collect::<Vec<_>>());
    }
}
