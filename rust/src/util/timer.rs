//! Wall-clock timing helpers used by the metrics layer and the bench
//! harnesses (criterion is unavailable offline; `benches/` use these).

use std::time::{Duration, Instant};

/// A resettable stopwatch that accumulates elapsed time across start/stop
/// intervals. Used to attribute MJ run time to phases (Fig. 8 breakdown).
#[derive(Debug)]
pub struct Stopwatch {
    acc: Duration,
    started: Option<Instant>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        Stopwatch { acc: Duration::ZERO, started: None }
    }

    /// Start (or restart) the current interval.
    pub fn start(&mut self) {
        self.started = Some(Instant::now());
    }

    /// Stop the current interval, folding it into the accumulator.
    pub fn stop(&mut self) {
        if let Some(t) = self.started.take() {
            self.acc += t.elapsed();
        }
    }

    /// Total accumulated time (not counting a still-running interval).
    pub fn total(&self) -> Duration {
        self.acc
    }

    /// Run `f`, attributing its wall time to this stopwatch.
    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        self.start();
        let out = f();
        self.stop();
        out
    }
}

/// Render a duration as a compact human string ("1.42s", "318ms", "12.5us").
pub fn format_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 100.0 {
        format!("{:.0}s", s)
    } else if s >= 1.0 {
        format!("{:.2}s", s)
    } else if s >= 1e-3 {
        format!("{:.1}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.1}us", s * 1e6)
    } else {
        format!("{}ns", d.as_nanos())
    }
}

/// Measure the median wall time of `f` over `iters` runs (plus one warmup).
/// A minimal criterion stand-in for the micro benchmarks.
pub fn bench_median<T>(iters: usize, mut f: impl FnMut() -> T) -> Duration {
    assert!(iters > 0);
    let _ = f(); // warmup
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        let out = f();
        samples.push(t.elapsed());
        std::hint::black_box(out);
    }
    samples.sort_unstable();
    samples[samples.len() / 2]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_accumulates() {
        let mut sw = Stopwatch::new();
        sw.time(|| std::thread::sleep(Duration::from_millis(5)));
        sw.time(|| std::thread::sleep(Duration::from_millis(5)));
        assert!(sw.total() >= Duration::from_millis(9));
    }

    #[test]
    fn stop_without_start_is_noop() {
        let mut sw = Stopwatch::new();
        sw.stop();
        assert_eq!(sw.total(), Duration::ZERO);
    }

    #[test]
    fn format_ranges() {
        assert_eq!(format_duration(Duration::from_secs(120)), "120s");
        assert_eq!(format_duration(Duration::from_millis(1500)), "1.50s");
        assert_eq!(format_duration(Duration::from_millis(3)), "3.0ms");
        assert_eq!(format_duration(Duration::from_micros(12)), "12.0us");
        assert_eq!(format_duration(Duration::from_nanos(90)), "90ns");
    }

    #[test]
    fn bench_median_returns_positive() {
        let d = bench_median(5, || (0..1000).sum::<u64>());
        assert!(d > Duration::ZERO);
    }
}
