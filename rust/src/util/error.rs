//! Minimal `anyhow` stand-in (the real crate is unavailable offline): a
//! string-backed error type, `anyhow!`/`bail!` macros, and a `Context`
//! extension trait for `Result` and `Option`. Only the surface this crate
//! actually uses is implemented.

/// A human-readable error. Context added via [`Context`] is prepended,
/// `anyhow`-style (`"outer: inner"`).
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }

    /// Wrap with an outer context message.
    pub fn context(self, ctx: impl std::fmt::Display) -> Error {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // `{e}` and the anyhow-style `{e:#}` both print the full message.
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error { msg: s }
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Error {
        Error { msg: s.to_string() }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error { msg: e.to_string() }
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Error {
        Error { msg: e.to_string() }
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Error {
        Error { msg: e.to_string() }
    }
}

/// `Result` defaulting to [`Error`], like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (or turn `None` into an error), like
/// `anyhow::Context`.
pub trait Context<T> {
    fn context<C: std::fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: std::fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: std::fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: std::fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: std::fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx.to_string()))
    }

    fn with_context<C: std::fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Build an [`Error`] from a format string, like `anyhow::anyhow!`.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Early-return an [`Error`], like `anyhow::bail!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*).into())
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_and_context() {
        let e = Error::msg("inner");
        assert_eq!(e.to_string(), "inner");
        assert_eq!(format!("{:#}", e.context("outer")), "outer: inner");
    }

    #[test]
    fn result_context_chains() {
        let r: std::result::Result<(), std::num::ParseIntError> = "x".parse::<u32>().map(|_| ());
        let e = r.context("parsing x").unwrap_err();
        assert!(e.to_string().starts_with("parsing x: "));
    }

    #[test]
    fn option_context() {
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "missing 7");
    }

    #[test]
    fn macros_build_errors() {
        fn fails() -> Result<()> {
            bail!("bad {}", 42)
        }
        assert_eq!(fails().unwrap_err().to_string(), "bad 42");
        assert_eq!(anyhow!("x{}", 1).to_string(), "x1");
    }
}
