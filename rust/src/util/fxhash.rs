//! FxHash (the Firefox/rustc multiply-xor hash): the std SipHash is far too
//! slow for the join group-count hot loop, and the `fxhash` crate is not
//! available offline.

use std::hash::{BuildHasherDefault, Hasher};

/// Fast non-cryptographic hasher for internal hash maps keyed by row codes
/// and entity ids. Not DoS-resistant — inputs are our own data.
#[derive(Default)]
pub struct FxHasher {
    hash: u64,
}

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

impl FxHasher {
    #[inline]
    fn add(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rem.len()].copy_from_slice(rem);
            self.add(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.add(n);
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u128(&mut self, n: u128) {
        self.add(n as u64);
        self.add((n >> 64) as u64);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.add(n as u64);
    }
}

pub type FxBuildHasher = BuildHasherDefault<FxHasher>;
/// HashMap with the fast hasher.
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, FxBuildHasher>;
/// HashSet with the fast hasher.
pub type FxHashSet<K> = std::collections::HashSet<K, FxBuildHasher>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_roundtrip() {
        let mut m: FxHashMap<Vec<u16>, u64> = FxHashMap::default();
        for i in 0..1000u16 {
            m.insert(vec![i, i + 1], i as u64);
        }
        assert_eq!(m.len(), 1000);
        assert_eq!(m[&vec![10u16, 11]], 10);
    }

    #[test]
    fn distinct_inputs_hash_differently_mostly() {
        use std::hash::{BuildHasher, Hash};
        let b = FxBuildHasher::default();
        let h = |x: u64| {
            let mut s = b.build_hasher();
            x.hash(&mut s);
            s.finish()
        };
        let mut seen = std::collections::HashSet::new();
        for i in 0..10_000u64 {
            seen.insert(h(i));
        }
        assert!(seen.len() > 9_990);
    }
}
