//! Deterministic, dependency-free fault-injection harness.
//!
//! A *failpoint* is a named site in the code (`worker.exec.panic`,
//! `store.write.torn`, …) that asks the registry "should I fire?" every time
//! execution passes through it. Failpoints are armed from a spec string:
//!
//! ```text
//! spec    := point ( ',' point )*
//! point   := name '=' trigger ( '@' arg )?
//! trigger := 'always'
//!          | 'hit:' count            # fire on the first `count` evaluations
//!          | 'prob:' p ':' seed      # fire with probability p (seeded Pcg64)
//! arg     := u64                     # site-specific payload (e.g. delay ms)
//! ```
//!
//! e.g. `MRSS_FAILPOINTS='worker.exec.panic=hit:1,store.read.corrupt=prob:0.5:42'`
//! or `mrss serve --failpoints 'worker.exec.delay=always@50'`.
//!
//! Arming happens either programmatically (`arm`, used by tests and the
//! `--failpoints` flag) or lazily from the `MRSS_FAILPOINTS` environment
//! variable on the first evaluation. Both triggers are deterministic:
//! hit-counts fire on exact evaluation ordinals and probability triggers draw
//! from a [`Pcg64`] seeded by the spec, so a failing chaos run reproduces
//! exactly from its spec string.
//!
//! Unless the crate is compiled with `cfg(any(test, feature = "failpoints"))`
//! the evaluation functions are `#[inline(always)]` constants — release
//! builds pay nothing for the instrumented sites.

#[cfg(any(test, feature = "failpoints"))]
use crate::util::rng::Pcg64;
use crate::util::error::Result;
#[cfg(any(test, feature = "failpoints"))]
use crate::bail;
#[cfg(any(test, feature = "failpoints"))]
use std::collections::HashMap;
#[cfg(any(test, feature = "failpoints"))]
use std::sync::Mutex;

#[cfg(any(test, feature = "failpoints"))]
enum Trigger {
    Always,
    /// Fire on the first `n` evaluations, then stay off.
    Hits(u64),
    /// Fire each evaluation with probability `p`, drawn from a seeded Pcg64.
    Prob(f64, Pcg64),
}

#[cfg(any(test, feature = "failpoints"))]
struct Point {
    trigger: Trigger,
    arg: Option<u64>,
    evals: u64,
    fired: u64,
}

#[cfg(any(test, feature = "failpoints"))]
struct Registry {
    points: HashMap<String, Point>,
    env_loaded: bool,
}

#[cfg(any(test, feature = "failpoints"))]
static REGISTRY: Mutex<Option<Registry>> = Mutex::new(None);

/// Environment variable consulted on the first failpoint evaluation.
pub const ENV_VAR: &str = "MRSS_FAILPOINTS";

#[cfg(any(test, feature = "failpoints"))]
fn parse_point(item: &str) -> Result<(String, Point)> {
    let (name, rest) = match item.split_once('=') {
        Some(x) => x,
        None => bail!("failpoint spec '{item}' is missing '=trigger'"),
    };
    let (trig, arg) = match rest.split_once('@') {
        Some((t, a)) => {
            let a: u64 = match a.parse() {
                Ok(v) => v,
                Err(_) => bail!("failpoint '{name}': bad arg '{a}' (want u64)"),
            };
            (t, Some(a))
        }
        None => (rest, None),
    };
    let trigger = if trig == "always" {
        Trigger::Always
    } else if let Some(n) = trig.strip_prefix("hit:") {
        match n.parse::<u64>() {
            Ok(n) => Trigger::Hits(n),
            Err(_) => bail!("failpoint '{name}': bad hit count '{n}'"),
        }
    } else if let Some(ps) = trig.strip_prefix("prob:") {
        let (p, seed) = match ps.split_once(':') {
            Some(x) => x,
            None => bail!("failpoint '{name}': prob trigger wants 'prob:<p>:<seed>'"),
        };
        let p: f64 = match p.parse() {
            Ok(v) if (0.0..=1.0).contains(&v) => v,
            _ => bail!("failpoint '{name}': bad probability '{p}'"),
        };
        let seed: u64 = match seed.parse() {
            Ok(v) => v,
            Err(_) => bail!("failpoint '{name}': bad seed '{seed}'"),
        };
        Trigger::Prob(p, Pcg64::seeded(seed))
    } else {
        bail!("failpoint '{name}': unknown trigger '{trig}' (want always | hit:<n> | prob:<p>:<seed>)");
    };
    Ok((
        name.trim().to_string(),
        Point { trigger, arg, evals: 0, fired: 0 },
    ))
}

#[cfg(any(test, feature = "failpoints"))]
fn with_registry<T>(f: impl FnOnce(&mut Registry) -> T) -> T {
    let mut guard = REGISTRY.lock().unwrap_or_else(|e| e.into_inner());
    let reg = guard.get_or_insert_with(|| Registry { points: HashMap::new(), env_loaded: false });
    if !reg.env_loaded {
        reg.env_loaded = true;
        if let Ok(spec) = std::env::var(ENV_VAR) {
            if !spec.trim().is_empty() {
                // Env arming is best-effort: a malformed spec is ignored
                // rather than panicking deep inside an arbitrary call site.
                for item in spec.split(',') {
                    if let Ok((name, point)) = parse_point(item.trim()) {
                        reg.points.insert(name, point);
                    }
                }
            }
        }
    }
    f(reg)
}

/// Arm failpoints from a spec string (see module docs for the grammar).
/// Re-arming a name replaces its trigger and resets its counters.
#[cfg(any(test, feature = "failpoints"))]
pub fn arm(spec: &str) -> Result<()> {
    let mut parsed = Vec::new();
    for item in spec.split(',') {
        let item = item.trim();
        if item.is_empty() {
            continue;
        }
        parsed.push(parse_point(item)?);
    }
    with_registry(|reg| {
        for (name, point) in parsed {
            reg.points.insert(name, point);
        }
    });
    Ok(())
}

/// No-op when failpoints are compiled out; errors so `--failpoints` on a
/// production binary is an explicit failure, not a silent ignore.
#[cfg(not(any(test, feature = "failpoints")))]
pub fn arm(_spec: &str) -> Result<()> {
    Err(crate::util::error::Error::msg(
        "failpoints are compiled out; rebuild with --features failpoints",
    ))
}

/// Disarm every failpoint (tests use this between scenarios).
#[cfg(any(test, feature = "failpoints"))]
pub fn disarm_all() {
    with_registry(|reg| reg.points.clear());
}

#[cfg(not(any(test, feature = "failpoints")))]
pub fn disarm_all() {}

/// Evaluate the named failpoint: returns `true` if it is armed and its
/// trigger fires for this evaluation.
#[cfg(any(test, feature = "failpoints"))]
pub fn fire(name: &str) -> bool {
    with_registry(|reg| {
        let point = match reg.points.get_mut(name) {
            Some(p) => p,
            None => return false,
        };
        point.evals += 1;
        let hit = match &mut point.trigger {
            Trigger::Always => true,
            Trigger::Hits(n) => point.fired < *n,
            Trigger::Prob(p, rng) => rng.chance(*p),
        };
        if hit {
            point.fired += 1;
        }
        hit
    })
}

#[cfg(not(any(test, feature = "failpoints")))]
#[inline(always)]
pub fn fire(_name: &str) -> bool {
    false
}

/// Evaluate the named failpoint and, when it fires, return its `@arg`
/// payload (defaulting to 0). Sites like `worker.exec.delay` read the arg
/// as milliseconds.
#[cfg(any(test, feature = "failpoints"))]
pub fn fire_arg(name: &str) -> Option<u64> {
    with_registry(|reg| {
        let point = reg.points.get_mut(name)?;
        point.evals += 1;
        let hit = match &mut point.trigger {
            Trigger::Always => true,
            Trigger::Hits(n) => point.fired < *n,
            Trigger::Prob(p, rng) => rng.chance(*p),
        };
        if hit {
            point.fired += 1;
            Some(point.arg.unwrap_or(0))
        } else {
            None
        }
    })
}

#[cfg(not(any(test, feature = "failpoints")))]
#[inline(always)]
pub fn fire_arg(_name: &str) -> Option<u64> {
    None
}

/// How many times the named failpoint has fired (0 if unknown). Tests use
/// this to assert a fault was actually injected.
#[cfg(any(test, feature = "failpoints"))]
pub fn fired_count(name: &str) -> u64 {
    with_registry(|reg| reg.points.get(name).map_or(0, |p| p.fired))
}

#[cfg(not(any(test, feature = "failpoints")))]
#[inline(always)]
pub fn fired_count(_name: &str) -> u64 {
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    // The registry is process-global, so each test uses unique point names
    // and the suite stays order-independent.

    #[test]
    fn hit_count_fires_exactly_n_times() {
        arm("t.hit.point=hit:2").unwrap();
        assert!(fire("t.hit.point"));
        assert!(fire("t.hit.point"));
        assert!(!fire("t.hit.point"));
        assert!(!fire("t.hit.point"));
        assert_eq!(fired_count("t.hit.point"), 2);
    }

    #[test]
    fn always_fires_and_carries_arg() {
        arm("t.always.point=always@37").unwrap();
        for _ in 0..5 {
            assert_eq!(fire_arg("t.always.point"), Some(37));
        }
    }

    #[test]
    fn unarmed_points_never_fire() {
        assert!(!fire("t.never.armed"));
        assert_eq!(fire_arg("t.never.armed"), None);
    }

    #[test]
    fn prob_trigger_is_seeded_and_in_range() {
        arm("t.prob.point=prob:0.5:42").unwrap();
        let fired: u32 = (0..1000).map(|_| fire("t.prob.point") as u32).sum();
        // Deterministic given the seed; sanity-check it is neither 0 nor 1000.
        assert!(fired > 300 && fired < 700, "fired {fired}/1000 at p=0.5");
        // Re-arming resets and reproduces the same draw sequence.
        arm("t.prob.point=prob:0.5:42").unwrap();
        let fired2: u32 = (0..1000).map(|_| fire("t.prob.point") as u32).sum();
        assert_eq!(fired, fired2);
    }

    #[test]
    fn re_arming_resets_counters() {
        arm("t.rearm.point=hit:1").unwrap();
        assert!(fire("t.rearm.point"));
        assert!(!fire("t.rearm.point"));
        arm("t.rearm.point=hit:1").unwrap();
        assert!(fire("t.rearm.point"));
    }

    #[test]
    fn bad_specs_are_rejected() {
        assert!(arm("no-equals-sign").is_err());
        assert!(arm("p=hit:notanumber").is_err());
        assert!(arm("p=prob:1.5:7").is_err());
        assert!(arm("p=prob:0.5").is_err());
        assert!(arm("p=whatever").is_err());
        assert!(arm("p=always@notanumber").is_err());
    }
}
