//! A minimal property-testing harness (the `proptest` crate is unavailable
//! offline). Provides seeded case generation with failure reporting: on a
//! failing case the harness reports the case index and the seed so the case
//! can be replayed deterministically.

use super::rng::Pcg64;

/// Run `cases` random property checks. `gen` builds a case from an RNG,
/// `check` returns `Err(reason)` on violation. Panics with a replayable
/// seed on the first failure.
pub fn run_prop<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    base_seed: u64,
    mut gen: impl FnMut(&mut Pcg64) -> T,
    mut check: impl FnMut(&T) -> Result<(), String>,
) {
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
        let mut rng = Pcg64::seeded(seed);
        let input = gen(&mut rng);
        if let Err(reason) = check(&input) {
            panic!(
                "property `{name}` failed at case {case} (seed {seed:#x}):\n  reason: {reason}\n  input: {input:#?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_valid_property() {
        run_prop(
            "sum_commutes",
            64,
            1,
            |r| (r.below(1000), r.below(1000)),
            |&(a, b)| {
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("addition not commutative".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property `always_fails` failed")]
    fn reports_failure_with_seed() {
        run_prop("always_fails", 8, 2, |r| r.below(10), |_| Err("nope".into()));
    }

    #[test]
    fn deterministic_generation() {
        let mut seen = Vec::new();
        run_prop(
            "collect",
            4,
            3,
            |r| r.next_u64(),
            |&x| {
                seen.push(x);
                Ok(())
            },
        );
        let mut seen2 = Vec::new();
        run_prop(
            "collect",
            4,
            3,
            |r| r.next_u64(),
            |&x| {
                seen2.push(x);
                Ok(())
            },
        );
        assert_eq!(seen, seen2);
    }
}
