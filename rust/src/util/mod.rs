//! Small self-contained utilities: deterministic RNG, timers, text tables,
//! error handling, and a hand-rolled property-testing harness.
//!
//! The build environment is fully offline with no registry access, so the
//! usual crates (`rand`, `criterion`, `proptest`, `anyhow`, `fxhash`) are
//! re-implemented here at the scale this project needs.

pub mod error;
pub mod failpoint;
pub mod fxhash;
pub mod proptest;
pub mod rng;
pub mod table;
pub mod timer;

pub use rng::Pcg64;
pub use timer::{format_duration, Stopwatch};
pub use table::TextTable;
