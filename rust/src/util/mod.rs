//! Small self-contained utilities: deterministic RNG, timers, text tables,
//! and a hand-rolled property-testing harness.
//!
//! The build environment is fully offline with only `xla` and `anyhow`
//! available, so the usual crates (`rand`, `criterion`, `proptest`) are
//! re-implemented here at the scale this project needs.

pub mod rng;
pub mod timer;
pub mod table;
pub mod proptest;
pub mod fxhash;

pub use rng::Pcg64;
pub use timer::{Stopwatch, format_duration};
pub use table::TextTable;
