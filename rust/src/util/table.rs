//! Plain-text table rendering for the bench harnesses, so each bench can
//! print rows that mirror the paper's tables.

/// A simple left/right-aligned text table builder.
#[derive(Debug, Default)]
pub struct TextTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        TextTable { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render with a separator under the header. First column left-aligned,
    /// the rest right-aligned (numeric convention).
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::new();
            for i in 0..ncol {
                if i > 0 {
                    s.push_str("  ");
                }
                if i == 0 {
                    s.push_str(&format!("{:<w$}", cells[i], w = widths[i]));
                } else {
                    s.push_str(&format!("{:>w$}", cells[i], w = widths[i]));
                }
            }
            s
        };
        let mut out = fmt_row(&self.header);
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// Format a large integer with thousands separators ("1,354,134").
pub fn commas(n: u128) -> String {
    let digits = n.to_string();
    let mut out = String::with_capacity(digits.len() + digits.len() / 3);
    for (i, ch) in digits.chars().enumerate() {
        if i > 0 && (digits.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(ch);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_alignment() {
        let mut t = TextTable::new(vec!["name", "count"]);
        t.row(vec!["alpha", "3"]);
        t.row(vec!["b", "12345"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[2].starts_with("alpha"));
        assert!(lines[3].ends_with("12345"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = TextTable::new(vec!["a", "b"]);
        t.row(vec!["only one"]);
    }

    #[test]
    fn commas_grouping() {
        assert_eq!(commas(0), "0");
        assert_eq!(commas(999), "999");
        assert_eq!(commas(1000), "1,000");
        assert_eq!(commas(1354134), "1,354,134");
        assert_eq!(commas(5030412758000000u128), "5,030,412,758,000,000");
    }
}
