//! In-memory relational database engine.
//!
//! This is the substrate the paper assumed from MySQL: entity and
//! relationship tables, key indexes, and the two query services the Möbius
//! Join needs (paper §3-4):
//!
//! * entity contingency tables `ct(1Atts(X))` — a single-table GROUP BY;
//! * positive-chain contingency tables
//!   `ct(1Atts(R), 2Atts(R) | R = T)` — a multi-way join of relationship
//!   tables with their entity tables plus GROUP BY (the paper's dynamic SQL
//!   `CREATE TABLE ct_T AS SELECT COUNT(*) ... GROUP BY ...`).
//!
//! Entities are dense ids `0..n` per population; value codes are `u16`
//! dictionary codes. Relationship tables carry per-tuple attribute columns
//! and hash/vector indexes on both key columns (the B+-tree stand-in).

mod join;

pub use join::JoinCounter;

use crate::schema::{AttrId, FoVarId, PopId, RelId, Schema, VarId};
use crate::util::fxhash::FxHashMap;
use std::sync::Arc;

/// One relationship table instance.
#[derive(Debug, Clone)]
pub struct RelTable {
    /// Related entity pairs `(first, second)`; a set (no duplicates).
    pub pairs: Vec<[u32; 2]>,
    /// Per-tuple descriptive attribute codes, one vec per rel attribute,
    /// in schema declaration order; each parallel to `pairs`.
    pub attrs: Vec<Vec<u16>>,
    /// Index: entity id (first position) -> tuple indices.
    by_first: Vec<Vec<u32>>,
    /// Index: entity id (second position) -> tuple indices.
    by_second: Vec<Vec<u32>>,
    /// Index: pair -> tuple index.
    by_pair: FxHashMap<(u32, u32), u32>,
}

impl RelTable {
    pub fn len(&self) -> usize {
        self.pairs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }

    /// Tuple indices whose first key equals `e`.
    pub fn tuples_by_first(&self, e: u32) -> &[u32] {
        self.by_first.get(e as usize).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Tuple indices whose second key equals `e`.
    pub fn tuples_by_second(&self, e: u32) -> &[u32] {
        self.by_second.get(e as usize).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Tuple index for an exact pair, if related.
    pub fn tuple_of_pair(&self, a: u32, b: u32) -> Option<u32> {
        self.by_pair.get(&(a, b)).copied()
    }
}

/// A database instance over a schema.
#[derive(Debug, Clone)]
pub struct Database {
    pub schema: Arc<Schema>,
    /// Number of entities per population.
    pub entity_counts: Vec<u32>,
    /// `entity_attrs[pop][k][e]` = code of the k-th attribute (declaration
    /// order within the population) of entity `e`.
    pub entity_attrs: Vec<Vec<Vec<u16>>>,
    /// One table per relationship type, schema order.
    pub rels: Vec<RelTable>,
}

/// Builder-style constructor used by the data generators and tests.
pub struct DatabaseBuilder {
    schema: Arc<Schema>,
    entity_counts: Vec<u32>,
    entity_attrs: Vec<Vec<Vec<u16>>>,
    rel_pairs: Vec<Vec<[u32; 2]>>,
    rel_attrs: Vec<Vec<Vec<u16>>>,
    rel_seen: Vec<FxHashMap<(u32, u32), ()>>,
}

impl DatabaseBuilder {
    pub fn new(schema: Arc<Schema>) -> Self {
        let np = schema.populations.len();
        let nr = schema.relationships.len();
        DatabaseBuilder {
            entity_counts: vec![0; np],
            entity_attrs: schema
                .populations
                .iter()
                .map(|p| vec![Vec::new(); p.attrs.len()])
                .collect(),
            rel_pairs: vec![Vec::new(); nr],
            rel_attrs: schema
                .relationships
                .iter()
                .map(|r| vec![Vec::new(); r.attrs.len()])
                .collect(),
            rel_seen: (0..nr).map(|_| FxHashMap::default()).collect(),
            schema,
        }
    }

    /// Add one entity with attribute codes in population declaration order.
    /// Returns the new entity id.
    pub fn add_entity(&mut self, pop: PopId, attr_codes: &[u16]) -> u32 {
        let p = &self.schema.populations[pop];
        assert_eq!(attr_codes.len(), p.attrs.len(), "attr code count mismatch");
        for (k, (&code, &attr)) in attr_codes.iter().zip(&p.attrs).enumerate() {
            assert!(
                (code as usize) < self.schema.attributes[attr].arity(),
                "code {code} out of range for attribute {}",
                self.schema.attributes[attr].name
            );
            self.entity_attrs[pop][k].push(code);
        }
        let id = self.entity_counts[pop];
        self.entity_counts[pop] += 1;
        id
    }

    /// Add one relationship tuple with its 2Att codes (declaration order).
    /// Duplicate pairs are ignored (a relationship is a set); returns
    /// whether the tuple was new.
    pub fn add_rel(&mut self, rel: RelId, a: u32, b: u32, attr_codes: &[u16]) -> bool {
        let r = &self.schema.relationships[rel];
        assert_eq!(attr_codes.len(), r.attrs.len(), "rel attr code count mismatch");
        assert!(a < self.entity_counts[r.pops[0]], "first key {a} out of range");
        assert!(b < self.entity_counts[r.pops[1]], "second key {b} out of range");
        if self.rel_seen[rel].insert((a, b), ()).is_some() {
            return false;
        }
        self.rel_pairs[rel].push([a, b]);
        for (k, (&code, &attr)) in attr_codes.iter().zip(&r.attrs).enumerate() {
            assert!((code as usize) < self.schema.attributes[attr].arity());
            self.rel_attrs[rel][k].push(code);
        }
        true
    }

    /// Check whether a pair is already related.
    pub fn has_rel(&self, rel: RelId, a: u32, b: u32) -> bool {
        self.rel_seen[rel].contains_key(&(a, b))
    }

    pub fn entity_count(&self, pop: PopId) -> u32 {
        self.entity_counts[pop]
    }

    /// Read back an inserted entity's attribute code (generators correlate
    /// relationship existence with entity attributes).
    pub fn peek_entity_attr(&self, pop: PopId, attr_idx: usize, e: u32) -> u16 {
        self.entity_attrs[pop][attr_idx][e as usize]
    }

    /// Freeze: build indexes.
    pub fn finish(self) -> Database {
        let mut rels = Vec::with_capacity(self.rel_pairs.len());
        for (rel_id, pairs) in self.rel_pairs.into_iter().enumerate() {
            let r = &self.schema.relationships[rel_id];
            let n1 = self.entity_counts[r.pops[0]] as usize;
            let n2 = self.entity_counts[r.pops[1]] as usize;
            let mut by_first = vec![Vec::new(); n1];
            let mut by_second = vec![Vec::new(); n2];
            let mut by_pair = FxHashMap::default();
            for (t, &[a, b]) in pairs.iter().enumerate() {
                by_first[a as usize].push(t as u32);
                by_second[b as usize].push(t as u32);
                by_pair.insert((a, b), t as u32);
            }
            rels.push(RelTable {
                pairs,
                attrs: self.rel_attrs[rel_id].clone(),
                by_first,
                by_second,
                by_pair,
            });
        }
        Database {
            schema: self.schema,
            entity_counts: self.entity_counts,
            entity_attrs: self.entity_attrs,
            rels,
        }
    }
}

impl Database {
    /// Attribute code of entity `e` for a (pop-local) attribute index.
    #[inline]
    pub fn entity_attr(&self, pop: PopId, attr_idx: usize, e: u32) -> u16 {
        self.entity_attrs[pop][attr_idx][e as usize]
    }

    /// Position of `attr` within its population's declaration order.
    pub fn attr_pos_in_pop(&self, pop: PopId, attr: AttrId) -> usize {
        self.schema.populations[pop]
            .attrs
            .iter()
            .position(|&a| a == attr)
            .expect("attribute not on this population")
    }

    /// Position of `attr` within its relationship's declaration order.
    pub fn attr_pos_in_rel(&self, rel: RelId, attr: AttrId) -> usize {
        self.schema.relationships[rel]
            .attrs
            .iter()
            .position(|&a| a == attr)
            .expect("attribute not on this relationship")
    }

    /// Total number of tuples over all tables (paper Table 2 "#Tuples").
    pub fn total_tuples(&self) -> u64 {
        let e: u64 = self.entity_counts.iter().map(|&n| n as u64).sum();
        let r: u64 = self.rels.iter().map(|t| t.len() as u64).sum();
        e + r
    }

    /// The population an FO variable ranges over.
    pub fn pop_of_fo(&self, fo: FoVarId) -> PopId {
        self.schema.fo_vars[fo].pop
    }

    /// Entity contingency table `ct(1Atts(X))` for one FO variable: a
    /// GROUP BY over the population's attribute columns. Columns are that
    /// variable's EntityAttr random variables. Built directly in packed
    /// form (group keys are the table's row keys under the schema-derived
    /// [`crate::ct::CtLayout`]) at whichever key width the layout needs:
    /// `u64` up to 64 bits, `u128` up to 128; only past that does the
    /// group-by hash code slices.
    pub fn ct_entity(&self, fo: FoVarId) -> crate::ct::CtTable {
        use crate::ct::{CtLayout, CtTable};
        let pop = self.pop_of_fo(fo);
        let vars: Vec<VarId> = self.schema.one_atts_of_fo(fo);
        let n = self.entity_counts[pop];
        if vars.is_empty() {
            // Attribute-less population: the nullary table counting it.
            return if n == 0 { CtTable::empty(vars) } else { CtTable::scalar(n as u64) };
        }
        // Attribute order within `vars` follows VarId order, which follows
        // population declaration order (builder emits them in order).
        let attr_idx: Vec<usize> = vars
            .iter()
            .map(|&v| match self.schema.random_vars[v] {
                crate::schema::RandomVar::EntityAttr { attr, .. } => {
                    self.attr_pos_in_pop(pop, attr)
                }
                _ => unreachable!(),
            })
            .collect();
        let layout = CtLayout::for_vars(&self.schema, &vars);
        if layout.fits() {
            return self.group_entities::<u64>(pop, &attr_idx, vars, layout);
        }
        if layout.fits2() {
            return self.group_entities::<u128>(pop, &attr_idx, vars, layout);
        }
        let mut groups: FxHashMap<Vec<u16>, u64> = FxHashMap::default();
        let mut key = vec![0u16; vars.len()];
        for e in 0..n {
            for (slot, &k) in attr_idx.iter().enumerate() {
                key[slot] = self.entity_attr(pop, k, e);
            }
            *groups.entry(key.clone()).or_insert(0) += 1;
        }
        let mut rows = Vec::with_capacity(groups.len() * vars.len());
        let mut counts = Vec::with_capacity(groups.len());
        for (k, c) in groups {
            rows.extend_from_slice(&k);
            counts.push(c);
        }
        CtTable::from_raw(vars, rows, counts)
    }

    /// Packed GROUP BY kernel behind [`Database::ct_entity`], generic over
    /// the key width the layout needs (all codes are real values, so
    /// encoding is the identity within each field).
    fn group_entities<K: crate::ct::KeyStore>(
        &self,
        pop: PopId,
        attr_idx: &[usize],
        vars: Vec<VarId>,
        layout: crate::ct::CtLayout,
    ) -> crate::ct::CtTable {
        let shifts: Vec<u32> = (0..vars.len()).map(|c| layout.col(c).shift).collect();
        let mut groups: FxHashMap<K, u64> = FxHashMap::default();
        for e in 0..self.entity_counts[pop] {
            let mut key = K::ZERO;
            for (slot, &k) in attr_idx.iter().enumerate() {
                key = key | (K::from_u64(self.entity_attr(pop, k, e) as u64) << shifts[slot]);
            }
            *groups.entry(key).or_insert(0) += 1;
        }
        let mut keyed: Vec<(K, u64)> = groups.into_iter().collect();
        crate::ct::radix_sort_pairs_k::<K>(&mut keyed, layout.total_bits());
        let mut keys = Vec::with_capacity(keyed.len());
        let mut counts = Vec::with_capacity(keyed.len());
        for (k, c) in keyed {
            keys.push(k);
            counts.push(c);
        }
        K::finish(vars, layout, keys, counts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schema::builder::university_schema;

    /// The paper's Figure 2 database instance.
    pub fn university_db() -> Database {
        let schema = Arc::new(university_schema());
        let mut b = DatabaseBuilder::new(schema.clone());
        // Students: jack(3,1), kim(2,1), paul(1,2)  [intelligence, ranking]
        let jack = b.add_entity(0, &[2, 0]);
        let kim = b.add_entity(0, &[1, 0]);
        let paul = b.add_entity(0, &[0, 1]);
        // Courses: 101(3,2)... wait: (rating, difficulty): 101(3,2->codes 2,1),
        // 102(2,1->1,0), 103(2,1->1,0)
        let c101 = b.add_entity(1, &[2, 1]);
        let c102 = b.add_entity(1, &[1, 0]);
        let _c103 = b.add_entity(1, &[1, 0]);
        // Professors: jim(2,1), oliver(3,1), david(2,2) [popularity, teachingability]
        let jim = b.add_entity(2, &[1, 0]);
        let oliver = b.add_entity(2, &[2, 0]);
        let david = b.add_entity(2, &[1, 1]);
        // Registration(S,C): (jack,101,grade1,sat1) (jack,102,2,2) (kim,102,3,1) (paul,101,2,1)
        b.add_rel(0, jack, c101, &[0, 0]);
        b.add_rel(0, jack, c102, &[1, 1]);
        b.add_rel(0, kim, c102, &[2, 0]);
        b.add_rel(0, paul, c101, &[1, 0]);
        // RA(P,S): (jack,oliver,High,3)->(oliver,jack) etc; attrs declared
        // (capability, salary): jack-oliver cap 3 sal High; kim-oliver 1 Low;
        // paul-jim 2 Med; kim-david 2 High
        b.add_rel(1, oliver, jack, &[2, 2]);
        b.add_rel(1, oliver, kim, &[0, 0]);
        b.add_rel(1, jim, paul, &[1, 1]);
        b.add_rel(1, david, kim, &[1, 2]);
        b.finish()
    }

    #[test]
    fn university_instance_shape() {
        let db = university_db();
        assert_eq!(db.total_tuples(), 9 + 8);
        assert_eq!(db.rels[0].len(), 4);
        assert_eq!(db.rels[1].len(), 4);
    }

    #[test]
    fn duplicate_rel_ignored() {
        let db_schema = Arc::new(university_schema());
        let mut b = DatabaseBuilder::new(db_schema);
        let s = b.add_entity(0, &[0, 0]);
        let c = b.add_entity(1, &[0, 0]);
        assert!(b.add_rel(0, s, c, &[0, 0]));
        assert!(!b.add_rel(0, s, c, &[1, 1]));
        assert!(b.has_rel(0, s, c));
        let db = b.finish();
        assert_eq!(db.rels[0].len(), 1);
    }

    #[test]
    fn indexes_consistent() {
        let db = university_db();
        let ra = &db.rels[1];
        // oliver (prof id 1) advises jack and kim
        assert_eq!(ra.tuples_by_first(1).len(), 2);
        // kim (student id 1) has two RAs
        assert_eq!(ra.tuples_by_second(1).len(), 2);
        assert!(ra.tuple_of_pair(1, 0).is_some());
        assert!(ra.tuple_of_pair(0, 0).is_none());
    }

    #[test]
    fn ct_entity_group_by() {
        let db = university_db();
        // Students: (3,1),(2,1),(1,2) -> 3 distinct combos, count 1 each
        let ct = db.ct_entity(0);
        assert_eq!(ct.len(), 3);
        assert_eq!(ct.total(), 3);
        // Courses: 102 and 103 share (2,1)
        let ct_c = db.ct_entity(1);
        assert_eq!(ct_c.len(), 2);
        assert_eq!(ct_c.total(), 3);
        assert_eq!(ct_c.count_of(&[1, 0]), 2);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn rel_key_bounds_checked() {
        let schema = Arc::new(university_schema());
        let mut b = DatabaseBuilder::new(schema);
        let s = b.add_entity(0, &[0, 0]);
        b.add_rel(0, s, 99, &[0, 0]);
    }
}

#[cfg(test)]
pub use tests::university_db;
