//! Positive-relationship contingency tables via multi-way join + GROUP BY.
//!
//! This is the engine service behind Algorithm 2 line 11 (and line 6 for
//! single relationships): `ct(1Atts(R), 2Atts(R) | R = T)` — the paper
//! computes it with dynamic SQL over the base tables; we run an index
//! backtracking join that propagates entity bindings (in the spirit of
//! tuple-ID propagation [Yin et al. 2004]) and accumulates group counts
//! without materializing the join.

use super::Database;
use crate::ct::{radix_sort_pairs, radix_sort_pairs_k, CtLayout, CtTable};
use crate::schema::{RandomVar, RelId, VarId};
use crate::util::fxhash::FxHashMap;

/// Where one ct column's code comes from during join enumeration.
enum ColSource {
    /// Entity attribute: (fo-slot index, population, attr position in pop).
    Entity { fo_slot: usize, pop: usize, attr_idx: usize },
    /// Relationship attribute: (rel-slot index, attr position in rel).
    Rel { rel_slot: usize, attr_idx: usize },
}

/// Join-based group counter over a database.
pub struct JoinCounter<'a> {
    pub db: &'a Database,
}

impl<'a> JoinCounter<'a> {
    pub fn new(db: &'a Database) -> Self {
        JoinCounter { db }
    }

    /// `ct(1Atts(rels) ∪ 2Atts(rels) | all rels = T)`.
    ///
    /// `rels` must be non-empty. Works for any relationship set (connected
    /// or not), but cost is the join size; the Möbius Join only calls it on
    /// chains.
    pub fn positive_ct(&self, rels: &[RelId]) -> CtTable {
        assert!(!rels.is_empty());
        let schema = &self.db.schema;
        let fo_vars = schema.fo_vars_of_rels(rels);
        let fo_slot_of = |fo: usize| fo_vars.iter().position(|&f| f == fo).unwrap();

        // Order relationships so each one shares an FO variable with the
        // prefix when possible (connected enumeration order).
        let order = connected_order(self.db, rels);

        // Column plan, in canonical VarId order.
        let vars: Vec<VarId> = schema.atts_of_rels(rels);
        let sources: Vec<ColSource> = vars
            .iter()
            .map(|&v| match schema.random_vars[v] {
                RandomVar::EntityAttr { fo, attr } => {
                    let pop = schema.fo_vars[fo].pop;
                    ColSource::Entity {
                        fo_slot: fo_slot_of(fo),
                        pop,
                        attr_idx: self.db.attr_pos_in_pop(pop, attr),
                    }
                }
                RandomVar::RelAttr { rel, attr } => ColSource::Rel {
                    rel_slot: order.iter().position(|&r| r == rel).unwrap(),
                    attr_idx: self.db.attr_pos_in_rel(rel, attr),
                },
                RandomVar::RelInd { .. } => unreachable!("indicators have no column source"),
            })
            .collect();

        // §Perf: group keys ARE the table's packed row keys. The layout
        // comes from the schema ([`CtLayout::for_vars`]), so the grouped
        // counts sort straight into a packed `CtTable` with no decode or
        // re-encode round trip — the table every downstream ct-algebra
        // operator consumes as-is. All codes here are real values (every
        // relationship is true, so no `NA`), hence encoding is the identity
        // within each field. Rows of 65–128 bits group as u128 keys that
        // become the two-word packed store directly; only past 128 bits do
        // we hash u16 slices.
        let layout = CtLayout::for_vars(schema, &vars);
        let shifts: Vec<u32> = (0..vars.len()).map(|c| layout.col(c).shift).collect();
        let mode = if layout.fits() {
            KeyMode::U64
        } else if layout.total_bits() <= 128 {
            KeyMode::U128
        } else {
            KeyMode::Wide
        };

        let mut state = JoinState {
            db: self.db,
            order: &order,
            fo_vars: &fo_vars,
            binding: vec![None; fo_vars.len()],
            tuple_choice: vec![0u32; order.len()],
            groups: FxHashMap::default(),
            packed_groups: FxHashMap::default(),
            packed128_groups: FxHashMap::default(),
            key_buf: vec![0u16; vars.len()],
            sources: &sources,
            shifts: &shifts,
            mode,
        };
        state.enumerate(0);

        match mode {
            KeyMode::U64 => {
                if vars.is_empty() {
                    // Attribute-less chain: normalize to the canonical
                    // nullary representation (scalar stores no keys).
                    let total: u64 = state.packed_groups.values().sum();
                    return if total == 0 { CtTable::empty(vars) } else { CtTable::scalar(total) };
                }
                let mut keyed: Vec<(u64, u64)> = state.packed_groups.into_iter().collect();
                radix_sort_pairs(&mut keyed, layout.total_bits());
                let mut keys = Vec::with_capacity(keyed.len());
                let mut counts = Vec::with_capacity(keyed.len());
                for (k, c) in keyed {
                    keys.push(k);
                    counts.push(c);
                }
                // Packed integer order == lexicographic row order: already
                // canonical.
                CtTable::from_sorted_packed(vars, layout, keys, counts)
            }
            KeyMode::U128 => {
                // Two-word tier: the group keys become the table's u128 row
                // keys as-is (previously this arm decoded into the row-major
                // wide store, pushing every downstream operator off the
                // packed path).
                let mut keyed: Vec<(u128, u64)> = state.packed128_groups.into_iter().collect();
                radix_sort_pairs_k::<u128>(&mut keyed, layout.total_bits());
                let mut keys = Vec::with_capacity(keyed.len());
                let mut counts = Vec::with_capacity(keyed.len());
                for (k, c) in keyed {
                    keys.push(k);
                    counts.push(c);
                }
                CtTable::from_sorted_packed2(vars, layout, keys, counts)
            }
            KeyMode::Wide => {
                let mut rows = Vec::with_capacity(state.groups.len() * vars.len());
                let mut counts = Vec::with_capacity(state.groups.len());
                for (k, c) in state.groups {
                    rows.extend_from_slice(&k);
                    counts.push(c);
                }
                CtTable::from_raw(vars, rows, counts)
            }
        }
    }
}

/// Reorder `rels` so each element shares an FO variable with the prefix
/// when the set is connected; disconnected components are appended in
/// input order (their enumeration degenerates to a cross scan).
fn connected_order(db: &Database, rels: &[RelId]) -> Vec<RelId> {
    let schema = &db.schema;
    let mut remaining: Vec<RelId> = rels.to_vec();
    let mut order = Vec::with_capacity(rels.len());
    let mut bound_fos: Vec<usize> = Vec::new();
    // Start from the smallest relationship table (cheapest outer loop).
    remaining.sort_by_key(|&r| db.rels[r].len());
    while !remaining.is_empty() {
        let pos = remaining
            .iter()
            .position(|&r| {
                schema.relationships[r].fo_vars.iter().any(|f| bound_fos.contains(f))
            })
            .unwrap_or(0);
        let r = remaining.remove(pos);
        bound_fos.extend(schema.relationships[r].fo_vars.iter().copied());
        order.push(r);
    }
    order
}

/// How group keys are represented during join enumeration, by packed width.
#[derive(Clone, Copy, PartialEq, Eq)]
enum KeyMode {
    /// ≤ 64 bits: keys double as the output table's packed row keys.
    U64,
    /// 65..=128 bits: transient u128 keys, decoded into the wide store.
    U128,
    /// > 128 bits: hash the u16 code slice.
    Wide,
}

struct JoinState<'a> {
    db: &'a Database,
    order: &'a [RelId],
    fo_vars: &'a [usize],
    /// Current entity binding per FO slot.
    binding: Vec<Option<u32>>,
    /// Chosen tuple index per rel slot.
    tuple_choice: Vec<u32>,
    groups: FxHashMap<Vec<u16>, u64>,
    packed_groups: FxHashMap<u64, u64>,
    packed128_groups: FxHashMap<u128, u64>,
    key_buf: Vec<u16>,
    sources: &'a [ColSource],
    /// Per-column bit shifts of the output `CtLayout` (§Perf).
    shifts: &'a [u32],
    mode: KeyMode,
}

impl JoinState<'_> {
    fn enumerate(&mut self, depth: usize) {
        if depth == self.order.len() {
            self.emit();
            return;
        }
        let rel = self.order[depth];
        let rt = &self.db.rels[rel];
        let r = &self.db.schema.relationships[rel];
        let slot1 = self.fo_vars.iter().position(|&f| f == r.fo_vars[0]).unwrap();
        let slot2 = self.fo_vars.iter().position(|&f| f == r.fo_vars[1]).unwrap();
        let b1 = self.binding[slot1];
        let b2 = self.binding[slot2];
        match (b1, b2) {
            (Some(a), Some(b)) => {
                if let Some(t) = rt.tuple_of_pair(a, b) {
                    self.tuple_choice[depth] = t;
                    self.enumerate(depth + 1);
                }
            }
            (Some(a), None) => {
                // Index scan on the first key; borrow checker needs the
                // tuple list copied out? No — iterate by index to avoid
                // holding a borrow across the recursive call.
                let n = rt.tuples_by_first(a).len();
                for i in 0..n {
                    let t = self.db.rels[rel].tuples_by_first(a)[i];
                    let b = self.db.rels[rel].pairs[t as usize][1];
                    self.tuple_choice[depth] = t;
                    self.binding[slot2] = Some(b);
                    self.enumerate(depth + 1);
                }
                self.binding[slot2] = None;
            }
            (None, Some(b)) => {
                let n = rt.tuples_by_second(b).len();
                for i in 0..n {
                    let t = self.db.rels[rel].tuples_by_second(b)[i];
                    let a = self.db.rels[rel].pairs[t as usize][0];
                    self.tuple_choice[depth] = t;
                    self.binding[slot1] = Some(a);
                    self.enumerate(depth + 1);
                }
                self.binding[slot1] = None;
            }
            (None, None) => {
                // Unconstrained scan (first rel of a component).
                for t in 0..rt.len() as u32 {
                    let [a, b] = self.db.rels[rel].pairs[t as usize];
                    self.tuple_choice[depth] = t;
                    self.binding[slot1] = Some(a);
                    self.binding[slot2] = Some(b);
                    self.enumerate(depth + 1);
                }
                self.binding[slot1] = None;
                self.binding[slot2] = None;
            }
        }
    }

    /// Value code of one output column at the current enumeration leaf.
    #[inline]
    fn code_of(&self, src: &ColSource) -> u16 {
        match *src {
            ColSource::Entity { fo_slot, pop, attr_idx } => {
                let e = self.binding[fo_slot].expect("unbound FO var at leaf");
                self.db.entity_attr(pop, attr_idx, e)
            }
            ColSource::Rel { rel_slot, attr_idx } => {
                let rel = self.order[rel_slot];
                let t = self.tuple_choice[rel_slot] as usize;
                self.db.rels[rel].attrs[attr_idx][t]
            }
        }
    }

    #[inline]
    fn emit(&mut self) {
        match self.mode {
            KeyMode::U64 => {
                let mut key = 0u64;
                for (slot, src) in self.sources.iter().enumerate() {
                    key |= (self.code_of(src) as u64) << self.shifts[slot];
                }
                *self.packed_groups.entry(key).or_insert(0) += 1;
                return;
            }
            KeyMode::U128 => {
                let mut key = 0u128;
                for (slot, src) in self.sources.iter().enumerate() {
                    key |= (self.code_of(src) as u128) << self.shifts[slot];
                }
                *self.packed128_groups.entry(key).or_insert(0) += 1;
                return;
            }
            KeyMode::Wide => {}
        }
        for (slot, src) in self.sources.iter().enumerate() {
            self.key_buf[slot] = match *src {
                ColSource::Entity { fo_slot, pop, attr_idx } => {
                    let e = self.binding[fo_slot].expect("unbound FO var at leaf");
                    self.db.entity_attr(pop, attr_idx, e)
                }
                ColSource::Rel { rel_slot, attr_idx } => {
                    let rel = self.order[rel_slot];
                    let t = self.tuple_choice[rel_slot] as usize;
                    self.db.rels[rel].attrs[attr_idx][t]
                }
            };
        }
        if let Some(c) = self.groups.get_mut(self.key_buf.as_slice()) {
            *c += 1;
        } else {
            self.groups.insert(self.key_buf.clone(), 1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::db::university_db;

    #[test]
    fn single_rel_positive_ct_matches_figure2() {
        let db = university_db();
        let jc = JoinCounter::new(&db);
        // RA(P,S) = rel 1: 4 tuples, each a distinct (prof, student) pair.
        let ct = jc.positive_ct(&[1]);
        assert_eq!(ct.total(), 4);
        // Columns: intelligence(S), ranking(S), popularity(P),
        // teachingability(P), capability(P,S), salary(P,S) = 6
        assert_eq!(ct.width(), 6);
        // The query from paper §2.2: intelligence=2, rank=1, popularity=3,
        // teachingability=1, RA=T has exactly one instantiation (kim,oliver).
        let s = &db.schema;
        let sel = ct.select(&[
            (s.var_by_name("intelligence(S)").unwrap(), 1), // "2" -> code 1
            (s.var_by_name("ranking(S)").unwrap(), 0),
            (s.var_by_name("popularity(P)").unwrap(), 2),
            (s.var_by_name("teachingability(P)").unwrap(), 0),
        ]);
        assert_eq!(sel.total(), 1);
    }

    #[test]
    fn two_rel_chain_join() {
        let db = university_db();
        let jc = JoinCounter::new(&db);
        // Chain Registration(S,C), RA(P,S): join on S.
        // Registrations: jack x2, kim x1, paul x1. RAs: jack x1, kim x2, paul x1.
        // Join size = 2*1 + 1*2 + 1*1 = 5.
        let ct = jc.positive_ct(&[0, 1]);
        assert_eq!(ct.total(), 5);
        // Columns: 2 S attrs + 2 C attrs + 2 P attrs + 2 Reg attrs + 2 RA attrs.
        assert_eq!(ct.width(), 10);
    }

    #[test]
    fn order_is_permutation() {
        let db = university_db();
        let o = connected_order(&db, &[0, 1]);
        let mut s = o.clone();
        s.sort_unstable();
        assert_eq!(s, vec![0, 1]);
    }

    #[test]
    fn empty_rel_gives_empty_ct() {
        use crate::db::DatabaseBuilder;
        use crate::schema::builder::university_schema;
        use std::sync::Arc;
        let schema = Arc::new(university_schema());
        let mut b = DatabaseBuilder::new(schema);
        b.add_entity(0, &[0, 0]);
        b.add_entity(1, &[0, 0]);
        b.add_entity(2, &[0, 0]);
        let db = b.finish();
        let jc = JoinCounter::new(&db);
        let ct = jc.positive_ct(&[0]);
        assert!(ct.is_empty());
    }

    #[test]
    fn self_relationship_join() {
        use crate::db::DatabaseBuilder;
        use crate::schema::SchemaBuilder;
        use std::sync::Arc;
        let mut sb = SchemaBuilder::new("toy");
        let c = sb.population("Country");
        sb.attr(c, "size", &["s", "b"]);
        sb.relationship("Borders", c, c);
        let schema = Arc::new(sb.finish());
        let mut b = DatabaseBuilder::new(schema.clone());
        let c0 = b.add_entity(c, &[0]);
        let c1 = b.add_entity(c, &[1]);
        let c2 = b.add_entity(c, &[1]);
        b.add_rel(0, c0, c1, &[]);
        b.add_rel(0, c1, c2, &[]);
        let db = b.finish();
        let jc = JoinCounter::new(&db);
        let ct = jc.positive_ct(&[0]);
        // Columns: size(C1), size(C2).
        assert_eq!(ct.width(), 2);
        assert_eq!(ct.total(), 2);
        // (c0 small, c1 big) and (c1 big, c2 big)
        assert_eq!(ct.count_of(&[0, 1]), 1);
        assert_eq!(ct.count_of(&[1, 1]), 1);
    }
}
