//! [`CtEngine`] implementation backed by the XLA runtime: the bulk
//! arithmetic of projection (segment sum) and subtraction (fused pivot)
//! runs in the AOT-compiled kernels, while row bookkeeping (grouping,
//! alignment) stays in rust. Falls back to the native implementation when
//! an input exceeds the artifact bucket ladder.
//!
//! Conversion shims: the packed-key [`CtTable`] decodes to a row-major
//! code matrix at the engine boundary and results re-enter through the
//! sorted-row constructor, so the kernels stay layout-agnostic.
//!
//! Results are bit-identical to [`NativeEngine`] (integer counts in f64 are
//! exact); `rust/tests/xla_vs_native.rs` asserts this end-to-end.
//!
//! [`NativeEngine`]: crate::mobius::NativeEngine

use super::XlaRuntime;
use crate::ct::{CtTable, SubtractError};
use crate::mobius::CtEngine;
use crate::schema::VarId;
use crate::util::fxhash::FxHashMap;

/// Execution engine that offloads bulk count arithmetic to XLA.
pub struct XlaEngine<'rt> {
    rt: &'rt XlaRuntime,
}

impl<'rt> XlaEngine<'rt> {
    pub fn new(rt: &'rt XlaRuntime) -> Self {
        XlaEngine { rt }
    }
}

impl CtEngine for XlaEngine<'_> {
    fn name(&self) -> &'static str {
        "xla"
    }

    /// π projection: rust computes the dense group index per row, XLA sums
    /// counts per group (`segsum` kernel).
    fn project(&self, ct: &CtTable, keep: &[VarId]) -> CtTable {
        let mut keep_sorted: Vec<VarId> = keep.to_vec();
        keep_sorted.sort_unstable();
        keep_sorted.dedup();
        let cols: Vec<usize> = keep_sorted
            .iter()
            .map(|&v| ct.col_of(v).expect("project: unknown var"))
            .collect();
        if cols.len() == ct.width() || ct.is_empty() || cols.is_empty() {
            return ct.project(keep);
        }
        // Group assignment (row bookkeeping stays on the coordinator).
        let w = ct.width();
        let matrix = ct.decode_rows();
        let mut gid_of: FxHashMap<Vec<u16>, u32> = FxHashMap::default();
        let mut keys: Vec<u16> = Vec::new();
        let mut ids: Vec<u32> = Vec::with_capacity(ct.len());
        let nw = cols.len();
        let mut buf = vec![0u16; nw];
        for i in 0..ct.len() {
            let r = &matrix[i * w..(i + 1) * w];
            for (slot, &c) in cols.iter().enumerate() {
                buf[slot] = r[c];
            }
            let id = match gid_of.get(buf.as_slice()) {
                Some(&g) => g,
                None => {
                    let g = gid_of.len() as u32;
                    gid_of.insert(buf.clone(), g);
                    keys.extend_from_slice(&buf);
                    g
                }
            };
            ids.push(id);
        }
        let counts: Vec<f64> = ct.counts.iter().map(|&c| c as f64).collect();
        match self.rt.segsum(&ids, &counts, gid_of.len()) {
            Ok(sums) => {
                let counts_u: Vec<u64> = sums.iter().map(|&s| s as u64).collect();
                CtTable::from_raw(keep_sorted, keys, counts_u)
            }
            Err(_) => ct.project(keep), // exceeds ladder: native fallback
        }
    }

    /// − subtraction via the fused pivot kernel: rust aligns the rows
    /// (merge pass over the sorted inputs), XLA computes
    /// `max(star - t, 0)` in bulk.
    fn subtract(&self, a: &CtTable, b: &CtTable) -> Result<CtTable, SubtractError> {
        if a.vars != b.vars {
            return Err(SubtractError::VarMismatch);
        }
        if a.width() == 0 || a.is_empty() || b.is_empty() {
            return a.subtract(b);
        }
        let w = a.width();
        let am = a.decode_rows();
        let bm = b.decode_rows();
        let arow = |i: usize| &am[i * w..(i + 1) * w];
        let brow = |j: usize| &bm[j * w..(j + 1) * w];
        // Alignment: b's rows must be a subset of a's.
        let mut t_aligned = vec![0.0f64; a.len()];
        let (mut i, mut j) = (0usize, 0usize);
        while j < b.len() {
            if i >= a.len() {
                return Err(SubtractError::MissingRow(brow(j).to_vec()));
            }
            match arow(i).cmp(brow(j)) {
                std::cmp::Ordering::Less => i += 1,
                std::cmp::Ordering::Greater => {
                    return Err(SubtractError::MissingRow(brow(j).to_vec()));
                }
                std::cmp::Ordering::Equal => {
                    if b.counts[j] > a.counts[i] {
                        return Err(SubtractError::CountUnderflow {
                            row: arow(i).to_vec(),
                            have: a.counts[i],
                            sub: b.counts[j],
                        });
                    }
                    t_aligned[i] = b.counts[j] as f64;
                    i += 1;
                    j += 1;
                }
            }
        }
        let star: Vec<f64> = a.counts.iter().map(|&c| c as f64).collect();
        let diff = match self.rt.pivot(&star, &t_aligned, 1.0) {
            Ok(d) => d,
            Err(_) => return a.subtract(b), // exceeds ladder: native fallback
        };
        // Rebuild, dropping zero rows; surviving rows keep sorted order.
        let mut rows = Vec::with_capacity(am.len());
        let mut counts = Vec::with_capacity(a.len());
        for (idx, &d) in diff.iter().enumerate() {
            if d > 0.0 {
                rows.extend_from_slice(arow(idx));
                counts.push(d as u64);
            }
        }
        if counts.is_empty() {
            return Ok(CtTable::empty(a.vars.clone()));
        }
        Ok(CtTable::from_sorted_rows(a.vars.clone(), rows, counts))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mobius::NativeEngine;

    fn runtime() -> Option<XlaRuntime> {
        XlaRuntime::load_default().ok()
    }

    #[test]
    fn project_bit_identical_to_native() {
        let Some(rt) = runtime() else {
            eprintln!("skipping (no artifacts)");
            return;
        };
        let e = XlaEngine::new(&rt);
        let n = NativeEngine;
        let ct = CtTable::from_raw(
            vec![1, 3, 5],
            vec![
                0, 0, 1, 0, 1, 0, 1, 0, 1, 1, 1, 0, 0, 0, 0, 2, 1, 1,
            ],
            vec![5, 7, 11, 13, 17, 19],
        );
        for keep in [vec![1], vec![3, 5], vec![1, 5], vec![1, 3, 5]] {
            assert_eq!(e.project(&ct, &keep), n.project(&ct, &keep), "keep={keep:?}");
        }
    }

    #[test]
    fn subtract_bit_identical_to_native() {
        let Some(rt) = runtime() else {
            eprintln!("skipping (no artifacts)");
            return;
        };
        let e = XlaEngine::new(&rt);
        let a = CtTable::from_raw(vec![0, 2], vec![0, 0, 0, 1, 1, 0], vec![10, 20, 30]);
        let b = CtTable::from_raw(vec![0, 2], vec![0, 1, 1, 0], vec![20, 5]);
        let native = a.subtract(&b).unwrap();
        let xla = e.subtract(&a, &b).unwrap();
        assert_eq!(native, xla);
        // Errors propagate identically.
        let bad = CtTable::from_raw(vec![0, 2], vec![1, 1], vec![1]);
        assert!(e.subtract(&a, &bad).is_err());
    }
}
