//! Stub runtime used when the `xla` feature is off (the default).
//!
//! [`XlaRuntime`] is uninhabited: its loaders always return `Err`, so a
//! value can never exist and every method body is statically unreachable
//! (`match self.void {}`). This keeps the full API surface compiling —
//! CLI `--engine xla`, benches, integration tests — while making "the
//! artifacts are unavailable" the only possible runtime outcome.

use crate::ct::{CtTable, SubtractError};
use crate::mobius::CtEngine;
use crate::schema::VarId;
use crate::util::error::Result;
use std::path::Path;

/// Uninhabited marker: proof that a stub `XlaRuntime` cannot be built.
#[derive(Debug, Clone, Copy)]
enum Void {}

/// Stub PJRT runtime (never constructible without the `xla` feature).
#[derive(Debug)]
pub struct XlaRuntime {
    void: Void,
}

impl XlaRuntime {
    /// Always fails: the crate was built without the `xla` feature.
    pub fn load(_dir: &Path) -> Result<XlaRuntime> {
        Err(crate::anyhow!(
            "built without the `xla` cargo feature; rebuild with --features xla \
             (and the xla PJRT bindings crate) to enable the AOT runtime"
        ))
    }

    /// Always fails: see [`XlaRuntime::load`].
    pub fn load_default() -> Result<XlaRuntime> {
        Self::load(Path::new("artifacts"))
    }

    pub fn num_artifacts(&self) -> usize {
        match self.void {}
    }

    /// Segment sum kernel (unreachable in stub builds).
    pub fn segsum(&self, _ids: &[u32], _counts: &[f64], _num_segments: usize) -> Result<Vec<f64>> {
        match self.void {}
    }

    /// Fused pivot kernel (unreachable in stub builds).
    pub fn pivot(&self, _star: &[f64], _t: &[f64], _scale: f64) -> Result<Vec<f64>> {
        match self.void {}
    }

    /// Batched symmetric uncertainty (unreachable in stub builds).
    pub fn su_batch(&self, _joints: &[(Vec<f64>, usize, usize)]) -> Result<Vec<f64>> {
        match self.void {}
    }

    /// Batched BN family scores (unreachable in stub builds).
    pub fn bnscore_batch(&self, _families: &[(Vec<f64>, usize, usize)]) -> Result<Vec<f64>> {
        match self.void {}
    }

    /// Batched association-rule metrics (unreachable in stub builds).
    pub fn lift_batch(
        &self,
        _body: &[f64],
        _head: &[f64],
        _joint: &[f64],
        _total: f64,
    ) -> Result<Vec<(f64, f64, f64)>> {
        match self.void {}
    }
}

/// Stub engine: only constructible from a (non-constructible) runtime, so
/// the `CtEngine` impl below can never actually run; it delegates to the
/// native implementations for completeness.
pub struct XlaEngine<'rt> {
    _rt: &'rt XlaRuntime,
}

impl<'rt> XlaEngine<'rt> {
    pub fn new(rt: &'rt XlaRuntime) -> Self {
        XlaEngine { _rt: rt }
    }
}

impl CtEngine for XlaEngine<'_> {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn project(&self, ct: &CtTable, keep: &[VarId]) -> CtTable {
        ct.project(keep)
    }

    fn subtract(&self, a: &CtTable, b: &CtTable) -> Result<CtTable, SubtractError> {
        a.subtract(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_loaders_always_error() {
        let e = XlaRuntime::load_default().unwrap_err();
        assert!(e.to_string().contains("xla"), "{e}");
        assert!(XlaRuntime::load(Path::new("/nope")).is_err());
    }
}
