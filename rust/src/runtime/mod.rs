//! PJRT/XLA runtime, gated behind the `xla` cargo feature.
//!
//! With `--features xla` (requires the `xla` PJRT bindings crate, not
//! vendored here) this module loads AOT-compiled HLO artifacts
//! (`artifacts/*.hlo.txt`, produced once by `make artifacts` — python never
//! runs on the request path) and executes them via a CPU PJRT client; see
//! [`pjrt`] for details.
//!
//! Without the feature (the default, and the only configuration the offline
//! build supports) the same public names exist as stubs: [`XlaRuntime`]
//! constructors always return an error, so `--engine xla`, the xla
//! integration tests, and the benches all skip cleanly at runtime with no
//! `cfg` noise at call sites.

#[cfg(feature = "xla")]
mod pjrt;
#[cfg(feature = "xla")]
mod xla_engine;

#[cfg(feature = "xla")]
pub use pjrt::{ManifestEntry, XlaRuntime};
#[cfg(feature = "xla")]
pub use xla_engine::XlaEngine;

#[cfg(not(feature = "xla"))]
mod stub;

#[cfg(not(feature = "xla"))]
pub use stub::{XlaEngine, XlaRuntime};
