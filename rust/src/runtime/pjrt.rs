//! PJRT runtime: load the AOT-compiled XLA artifacts (`artifacts/*.hlo.txt`,
//! produced once by `make artifacts` — python never runs on the request
//! path) and execute them from the coordinator.
//!
//! Artifacts are compiled at a ladder of static bucket shapes
//! (`manifest.txt`); inputs are padded up to the nearest bucket and one
//! compiled `PjRtLoadedExecutable` is cached per artifact. All count
//! arithmetic is f64 (exact for integer counts below 2^53), so results are
//! bit-identical to the native engine — asserted by the integration tests.
//!
//! Only compiled with `--features xla` (requires the `xla` bindings crate).

use crate::util::error::{Context, Result};
use crate::{anyhow, bail};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// One artifact from `manifest.txt`.
#[derive(Debug, Clone)]
pub struct ManifestEntry {
    pub kind: String,
    pub params: HashMap<String, usize>,
    pub file: String,
}

impl ManifestEntry {
    fn param(&self, k: &str) -> usize {
        self.params[k]
    }
}

/// A loaded PJRT runtime with lazily-compiled executables.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    dir: PathBuf,
    entries: Vec<ManifestEntry>,
    // Mutex (not RefCell) so the runtime is Sync: the parallel Möbius Join
    // requires its CtEngine to be shareable across worker threads.
    execs: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl std::fmt::Debug for XlaRuntime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "XlaRuntime({} artifacts @ {})", self.entries.len(), self.dir.display())
    }
}

impl XlaRuntime {
    /// Load the artifact directory (reads `manifest.txt`, creates the PJRT
    /// CPU client; compilation is lazy per artifact).
    pub fn load(dir: &Path) -> Result<XlaRuntime> {
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {}", manifest.display()))?;
        let mut entries = Vec::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut parts: Vec<&str> = line.split_whitespace().collect();
            if parts.len() < 2 {
                bail!("malformed manifest line: {line}");
            }
            let file = parts.pop().unwrap().to_string();
            let kind = parts.remove(0).to_string();
            let mut params = HashMap::new();
            for p in parts {
                let (k, v) = p
                    .split_once('=')
                    .ok_or_else(|| anyhow!("malformed manifest param `{p}`"))?;
                params.insert(k.to_string(), v.parse::<usize>()?);
            }
            entries.push(ManifestEntry { kind, params, file });
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(XlaRuntime { client, dir: dir.to_path_buf(), entries, execs: Mutex::new(HashMap::new()) })
    }

    /// Load from the conventional location (`$MRSS_ARTIFACTS` or
    /// `<repo>/artifacts`).
    pub fn load_default() -> Result<XlaRuntime> {
        let dir = std::env::var("MRSS_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
        Self::load(&dir)
    }

    /// Number of artifacts in the manifest.
    pub fn num_artifacts(&self) -> usize {
        self.entries.len()
    }

    /// Smallest bucket of `kind` satisfying all `(param >= value)` bounds.
    fn pick_bucket(&self, kind: &str, bounds: &[(&str, usize)]) -> Result<&ManifestEntry> {
        self.entries
            .iter()
            .filter(|e| e.kind == kind)
            .filter(|e| bounds.iter().all(|&(k, v)| e.params.get(k).is_some_and(|&p| p >= v)))
            .min_by_key(|e| e.params.values().product::<usize>())
            .ok_or_else(|| {
                anyhow!("no `{kind}` bucket satisfies {bounds:?} (input exceeds ladder)")
            })
    }

    /// Compile-on-first-use, then execute. Returns the flattened output
    /// tuple.
    fn run(&self, entry_file: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        {
            let execs = self.execs.lock().unwrap();
            if let Some(exe) = execs.get(entry_file) {
                return self.exec_with(exe, inputs);
            }
        }
        let path = self.dir.join(entry_file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))?;
        let out = self.exec_with(&exe, inputs);
        self.execs.lock().unwrap().insert(entry_file.to_string(), exe);
        out
    }

    fn exec_with(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("pjrt execute: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        // aot.py lowers with return_tuple=True: unpack the tuple.
        lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))
    }

    /// Segment sum: `out[k] = Σ counts[i] where ids[i] == k` for
    /// `k < num_segments`. Pads to the nearest `(n, k)` bucket.
    pub fn segsum(&self, ids: &[u32], counts: &[f64], num_segments: usize) -> Result<Vec<f64>> {
        assert_eq!(ids.len(), counts.len());
        let entry =
            self.pick_bucket("segsum", &[("n", ids.len()), ("k", num_segments)])?.clone();
        let (n, k) = (entry.param("n"), entry.param("k"));
        let mut ids_pad: Vec<i32> = ids.iter().map(|&i| i as i32).collect();
        ids_pad.resize(n, k as i32); // out-of-range ids are dropped
        let mut counts_pad = counts.to_vec();
        counts_pad.resize(n, 0.0);
        let out = self.run(
            &entry.file,
            &[xla::Literal::vec1(&ids_pad), xla::Literal::vec1(&counts_pad)],
        )?;
        let sums = out[0].to_vec::<f64>().map_err(|e| anyhow!("{e:?}"))?;
        Ok(sums[..num_segments].to_vec())
    }

    /// Fused pivot arithmetic: `max(star * scale - t, 0)` elementwise.
    pub fn pivot(&self, star: &[f64], t: &[f64], scale: f64) -> Result<Vec<f64>> {
        assert_eq!(star.len(), t.len());
        let entry = self.pick_bucket("pivot", &[("n", star.len())])?.clone();
        let n = entry.param("n");
        let real = star.len();
        let mut s = star.to_vec();
        s.resize(n, 0.0);
        let mut tt = t.to_vec();
        tt.resize(n, 0.0);
        let out = self.run(
            &entry.file,
            &[
                xla::Literal::vec1(&s),
                xla::Literal::vec1(&tt),
                xla::Literal::vec1(&[scale]),
            ],
        )?;
        let f = out[0].to_vec::<f64>().map_err(|e| anyhow!("{e:?}"))?;
        Ok(f[..real].to_vec())
    }

    /// Batched symmetric uncertainty. Each joint is a `v1 x v2` count
    /// matrix (row-major); matrices are zero-padded into the bucket's
    /// `v x v` cells (zero cells do not change entropies).
    pub fn su_batch(&self, joints: &[(Vec<f64>, usize, usize)]) -> Result<Vec<f64>> {
        if joints.is_empty() {
            return Ok(Vec::new());
        }
        let vmax = joints.iter().map(|&(_, v1, v2)| v1.max(v2)).max().unwrap();
        let entry = self.pick_bucket("su", &[("b", 1), ("v", vmax)])?.clone();
        let (b, v) = (entry.param("b"), entry.param("v"));
        let mut out = Vec::with_capacity(joints.len());
        for chunk in joints.chunks(b) {
            let mut data = vec![0.0f64; b * v * v];
            for (bi, (m, v1, v2)) in chunk.iter().enumerate() {
                assert_eq!(m.len(), v1 * v2);
                for r in 0..*v1 {
                    for c in 0..*v2 {
                        data[bi * v * v + r * v + c] = m[r * v2 + c];
                    }
                }
            }
            let lit = xla::Literal::vec1(&data)
                .reshape(&[b as i64, v as i64, v as i64])
                .map_err(|e| anyhow!("{e:?}"))?;
            let res = self.run(&entry.file, &[lit])?;
            let sus = res[0].to_vec::<f64>().map_err(|e| anyhow!("{e:?}"))?;
            out.extend_from_slice(&sus[..chunk.len()]);
        }
        Ok(out)
    }

    /// Batched BN family scores. Each family is a `p x c` count matrix
    /// (row-major). Falls back with an error if `p` exceeds the ladder.
    pub fn bnscore_batch(&self, families: &[(Vec<f64>, usize, usize)]) -> Result<Vec<f64>> {
        if families.is_empty() {
            return Ok(Vec::new());
        }
        let pmax = families.iter().map(|&(_, p, _)| p).max().unwrap();
        let cmax = families.iter().map(|&(_, _, c)| c).max().unwrap();
        let entry = self.pick_bucket("bnscore", &[("b", 1), ("p", pmax), ("c", cmax)])?.clone();
        let (b, p, c) = (entry.param("b"), entry.param("p"), entry.param("c"));
        let mut out = Vec::with_capacity(families.len());
        for chunk in families.chunks(b) {
            let mut data = vec![0.0f64; b * p * c];
            for (bi, (m, fp, fc)) in chunk.iter().enumerate() {
                assert_eq!(m.len(), fp * fc);
                for r in 0..*fp {
                    for cc in 0..*fc {
                        data[bi * p * c + r * c + cc] = m[r * fc + cc];
                    }
                }
            }
            let lit = xla::Literal::vec1(&data)
                .reshape(&[b as i64, p as i64, c as i64])
                .map_err(|e| anyhow!("{e:?}"))?;
            let res = self.run(&entry.file, &[lit])?;
            let scores = res[0].to_vec::<f64>().map_err(|e| anyhow!("{e:?}"))?;
            out.extend_from_slice(&scores[..chunk.len()]);
        }
        Ok(out)
    }

    /// Batched association-rule metrics: returns (support, confidence,
    /// lift) triples.
    pub fn lift_batch(
        &self,
        body: &[f64],
        head: &[f64],
        joint: &[f64],
        total: f64,
    ) -> Result<Vec<(f64, f64, f64)>> {
        if body.is_empty() {
            return Ok(Vec::new());
        }
        let entry = self.pick_bucket("lift", &[("b", 1)])?.clone();
        let b = entry.param("b");
        let mut out = Vec::with_capacity(body.len());
        let mut i = 0;
        while i < body.len() {
            let hi = (i + b).min(body.len());
            let mut bv = body[i..hi].to_vec();
            let mut hv = head[i..hi].to_vec();
            let mut jv = joint[i..hi].to_vec();
            bv.resize(b, 0.0);
            hv.resize(b, 0.0);
            jv.resize(b, 0.0);
            let tv = vec![total; b];
            let res = self.run(
                &entry.file,
                &[
                    xla::Literal::vec1(&bv),
                    xla::Literal::vec1(&hv),
                    xla::Literal::vec1(&jv),
                    xla::Literal::vec1(&tv),
                ],
            )?;
            let sup = res[0].to_vec::<f64>().map_err(|e| anyhow!("{e:?}"))?;
            let conf = res[1].to_vec::<f64>().map_err(|e| anyhow!("{e:?}"))?;
            let lift = res[2].to_vec::<f64>().map_err(|e| anyhow!("{e:?}"))?;
            for k in 0..(hi - i) {
                out.push((sup[k], conf[k], lift[k]));
            }
            i = hi;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<XlaRuntime> {
        match XlaRuntime::load_default() {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("skipping runtime test (run `make artifacts` first): {e}");
                None
            }
        }
    }

    #[test]
    fn segsum_roundtrip() {
        let Some(rt) = runtime() else { return };
        let ids: Vec<u32> = vec![0, 1, 2, 1, 0, 5];
        let counts = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let out = rt.segsum(&ids, &counts, 8).unwrap();
        assert_eq!(out, vec![6.0, 6.0, 3.0, 0.0, 0.0, 6.0, 0.0, 0.0]);
    }

    #[test]
    fn pivot_roundtrip() {
        let Some(rt) = runtime() else { return };
        let star = vec![5.0, 3.0, 2.0];
        let t = vec![4.0, 9.0, 0.0];
        let out = rt.pivot(&star, &t, 3.0).unwrap();
        assert_eq!(out, vec![11.0, 0.0, 6.0]);
    }

    #[test]
    fn su_matches_known_values() {
        let Some(rt) = runtime() else { return };
        // Perfectly dependent 2x2 joint: SU = 1. Independent uniform: SU = 0.
        let dep = (vec![5.0, 0.0, 0.0, 5.0], 2, 2);
        let indep = (vec![4.0, 4.0, 4.0, 4.0], 2, 2);
        let out = rt.su_batch(&[dep, indep]).unwrap();
        assert!((out[0] - 1.0).abs() < 1e-12, "dep su = {}", out[0]);
        assert!(out[1].abs() < 1e-12, "indep su = {}", out[1]);
    }

    #[test]
    fn bnscore_matches_hand_computation() {
        let Some(rt) = runtime() else { return };
        // One family, p=2 parent configs, c=2 values: counts [[3,1],[0,4]].
        // L = (Σ n log n - Σ n_p log n_p) / N
        let n: f64 = 8.0;
        let expect = ((3f64 * 3f64.ln() + 1.0 * 1f64.ln() + 4.0 * 4f64.ln())
            - (4f64 * 4f64.ln() + 4.0 * 4f64.ln()))
            / n;
        let out = rt.bnscore_batch(&[(vec![3.0, 1.0, 0.0, 4.0], 2, 2)]).unwrap();
        assert!((out[0] - expect).abs() < 1e-12, "{} vs {expect}", out[0]);
    }

    #[test]
    fn lift_roundtrip() {
        let Some(rt) = runtime() else { return };
        let out = rt.lift_batch(&[10.0], &[20.0], &[5.0], 100.0).unwrap();
        let (sup, conf, lift) = out[0];
        assert!((sup - 0.05).abs() < 1e-12);
        assert!((conf - 0.5).abs() < 1e-12);
        assert!((lift - 2.5).abs() < 1e-12);
    }

    #[test]
    fn oversized_input_errors_cleanly() {
        let Some(rt) = runtime() else { return };
        let ids = vec![0u32; 1 << 21];
        let counts = vec![1.0; 1 << 21];
        assert!(rt.segsum(&ids, &counts, 10).is_err());
    }
}
