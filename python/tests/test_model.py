"""L2 correctness: model graphs vs oracles + known closed-form values."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=16),
    v1=st.integers(min_value=1, max_value=8),
    v2=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_su_matches_ref(b, v1, v2, seed):
    rng = np.random.default_rng(seed)
    j = rng.integers(0, 50, size=(b, v1, v2)).astype(np.float64)
    got = np.array(model.su_model(jnp.array(j))[0])
    want = np.array(ref.su_ref(jnp.array(j)))
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


def test_su_known_values():
    dep = np.zeros((1, 2, 2))
    dep[0, 0, 0] = dep[0, 1, 1] = 5.0
    assert abs(float(model.su_model(jnp.array(dep))[0][0]) - 1.0) < 1e-12
    ind = np.full((1, 2, 2), 4.0)
    assert abs(float(model.su_model(jnp.array(ind))[0][0])) < 1e-12


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=8),
    p=st.integers(min_value=1, max_value=16),
    c=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_bnscore_matches_ref(b, p, c, seed):
    rng = np.random.default_rng(seed)
    counts = rng.integers(0, 40, size=(b, p, c)).astype(np.float64)
    got = np.array(model.bnscore_model(jnp.array(counts))[0])
    want = np.array(ref.bn_family_ref(jnp.array(counts)))
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-12)


def test_bnscore_deterministic_family_is_zero():
    # Child fully determined by parent: log-likelihood loss is 0.
    m = np.zeros((1, 2, 2))
    m[0, 0, 0] = 7.0
    m[0, 1, 1] = 3.0
    assert abs(float(model.bnscore_model(jnp.array(m))[0][0])) < 1e-12


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=64),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_lift_matches_ref(b, seed):
    rng = np.random.default_rng(seed)
    total = 1000.0
    body = rng.integers(0, 500, size=b).astype(np.float64)
    head = rng.integers(0, 500, size=b).astype(np.float64)
    joint = np.minimum(body, head) * rng.uniform(0, 1, size=b)
    args = [jnp.array(x) for x in (body, head, joint, np.full(b, total))]
    got = model.lift_model(*args)
    want = ref.lift_ref(*args)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.array(g), np.array(w), rtol=1e-12)


def test_segsum_model_projection_semantics():
    # Projection of a tiny ct: rows (a=0):3, (a=1):4, (a=0):5 -> [8, 4].
    from compile.kernels.segsum import BLOCK_N

    ids = np.full(BLOCK_N, 2, dtype=np.int32)
    counts = np.zeros(BLOCK_N)
    ids[:3] = [0, 1, 0]
    counts[:3] = [3.0, 4.0, 5.0]
    out = np.array(model.segsum_model(jnp.array(ids), jnp.array(counts), 2)[0])
    np.testing.assert_array_equal(out, [8.0, 4.0])
