"""AOT export sanity: every artifact lowers to parseable HLO text and the
manifest enumerates them all."""

import os

import pytest

from compile import aot


def test_build_all_produces_manifest_lines():
    # Only lower the smallest bucket of each kind (full ladder is exercised
    # by `make artifacts`); patch the ladders for speed.
    orig = (aot.SEGSUM_BUCKETS, aot.PIVOT_BUCKETS, aot.SU_BUCKETS,
            aot.BNSCORE_BUCKETS, aot.LIFT_BUCKETS)
    try:
        aot.SEGSUM_BUCKETS = [(8192, 1024)]
        aot.PIVOT_BUCKETS = [8192]
        aot.SU_BUCKETS = [(256, 8)]
        aot.BNSCORE_BUCKETS = [(256, 256, 8)]
        aot.LIFT_BUCKETS = [4096]
        arts = list(aot.build_all())
    finally:
        (aot.SEGSUM_BUCKETS, aot.PIVOT_BUCKETS, aot.SU_BUCKETS,
         aot.BNSCORE_BUCKETS, aot.LIFT_BUCKETS) = orig
    assert len(arts) == 5
    for name, text, line in arts:
        assert text.startswith("HloModule"), f"{name} is not HLO text"
        assert name in line
        # The rust loader keys on ENTRY; make sure it's present.
        assert "ENTRY" in text


def test_artifacts_dir_if_built():
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    if not os.path.exists(os.path.join(art, "manifest.txt")):
        pytest.skip("artifacts not built (run `make artifacts`)")
    lines = open(os.path.join(art, "manifest.txt")).read().splitlines()
    assert len(lines) >= 10
    for line in lines:
        fname = line.split()[-1]
        path = os.path.join(art, fname)
        assert os.path.exists(path), f"missing artifact {fname}"
        head = open(path).read(64)
        assert head.startswith("HloModule")
