"""L1 correctness: every Pallas kernel vs its pure-jnp oracle in ref.py.

Hypothesis sweeps shapes/values; count arithmetic must match exactly
(integer-valued f64), entropy terms to tight float tolerance.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.pivot import BLOCK_N as PIVOT_BLOCK
from compile.kernels.pivot import pivot
from compile.kernels.segsum import BLOCK_N as SEGSUM_BLOCK
from compile.kernels.segsum import segsum
from compile.kernels.xlogx import BLOCK_N as XLOGX_BLOCK
from compile.kernels.xlogx import xlogx


def _pad_to(x, block, fill):
    pad = (-len(x)) % block
    return np.concatenate([x, np.full(pad, fill, dtype=x.dtype)])


# ---------- segsum ----------

@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=3000),
    k=st.integers(min_value=1, max_value=64),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_segsum_matches_ref(n, k, seed):
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, k + 2, size=n).astype(np.int32)  # some out-of-range
    counts = rng.integers(0, 1000, size=n).astype(np.float64)
    ids_p = _pad_to(ids, SEGSUM_BLOCK, k)  # pad ids out of range
    counts_p = _pad_to(counts, SEGSUM_BLOCK, 0.0)
    got = np.array(segsum(jnp.array(ids_p), jnp.array(counts_p), k))
    want = np.array(ref.segsum_ref(jnp.array(ids), jnp.array(counts), k))
    np.testing.assert_array_equal(got, want)


def test_segsum_mxu_body_matches_scatter_body():
    rng = np.random.default_rng(0)
    ids = rng.integers(0, 32, size=SEGSUM_BLOCK * 2).astype(np.int32)
    counts = rng.integers(0, 100, size=SEGSUM_BLOCK * 2).astype(np.float64)
    a = np.array(segsum(jnp.array(ids), jnp.array(counts), 32, body="scatter"))
    b = np.array(segsum(jnp.array(ids), jnp.array(counts), 32, body="mxu"))
    np.testing.assert_array_equal(a, b)


def test_segsum_empty_segments():
    ids = jnp.full((SEGSUM_BLOCK,), 10, dtype=jnp.int32)  # all out of range
    counts = jnp.ones((SEGSUM_BLOCK,), dtype=jnp.float64)
    out = np.array(segsum(ids, counts, 10))
    np.testing.assert_array_equal(out, np.zeros(10))


def test_segsum_rejects_unaligned():
    with pytest.raises(AssertionError):
        segsum(jnp.zeros(3, jnp.int32), jnp.zeros(3), 4)


# ---------- pivot ----------

@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=5000),
    scale=st.integers(min_value=1, max_value=50),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_pivot_matches_ref(n, scale, seed):
    rng = np.random.default_rng(seed)
    star = rng.integers(0, 10000, size=n).astype(np.float64)
    t = np.minimum(star * scale, rng.integers(0, 10000, size=n)).astype(np.float64)
    sp = _pad_to(star, PIVOT_BLOCK, 0.0)
    tp = _pad_to(t, PIVOT_BLOCK, 0.0)
    got = np.array(pivot(jnp.array(sp), jnp.array(tp), jnp.array([float(scale)])))[:n]
    want = np.array(ref.pivot_ref(jnp.array(star), jnp.array(t), float(scale)))
    np.testing.assert_array_equal(got, want)


def test_pivot_equation1_university():
    # Paper Figure 5: |P|x|S| = 9 pairs, 4 RA tuples -> 5 false pairs.
    star = jnp.array([9.0] + [0.0] * (PIVOT_BLOCK - 1))
    t = jnp.array([4.0] + [0.0] * (PIVOT_BLOCK - 1))
    out = np.array(pivot(star, t, jnp.array([1.0])))
    assert out[0] == 5.0


# ---------- xlogx ----------

@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=5000),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_xlogx_matches_ref(n, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 100000, size=n).astype(np.float64)
    xp = _pad_to(x, XLOGX_BLOCK, 0.0)
    got = np.array(xlogx(jnp.array(xp)))[:n]
    want = np.array(ref.xlogx_ref(jnp.array(x)))
    np.testing.assert_allclose(got, want, rtol=1e-15)


def test_xlogx_zero_convention():
    x = jnp.zeros((XLOGX_BLOCK,), dtype=jnp.float64)
    out = np.array(xlogx(x))
    np.testing.assert_array_equal(out, np.zeros(XLOGX_BLOCK))
