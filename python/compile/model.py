"""L2: JAX compute graphs for the statistics pipeline, calling the L1
Pallas kernels.

These are the graphs AOT-lowered by `compile.aot` into `artifacts/*.hlo.txt`
and executed from the rust coordinator via PJRT (python never runs on the
request path):

* ``segsum_model``   — ct-algebra projection aggregation (GROUP BY sum);
* ``pivot_model``    — Equation-1 fused count arithmetic;
* ``su_model``       — batched symmetric uncertainty for CFS feature
  selection (Table 5);
* ``bnscore_model``  — batched relational pseudo log-likelihood of BN
  families (Tables 7-8);
* ``lift_model``     — batched association-rule support/confidence/lift
  (Table 6).

All count inputs are f64: integer counts are exact up to 2**53, so the
XLA engine is bit-compatible with the native rust engine.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from .kernels.pivot import pivot  # noqa: E402
from .kernels.segsum import segsum  # noqa: E402
from .kernels.xlogx import xlogx  # noqa: E402

# Entropy-term helper: flatten-to-kernel then reshape back. Pads the flat
# vector to the kernel block size.


def _xlogx_nd(x):
    flat = x.reshape(-1)
    from .kernels.xlogx import BLOCK_N

    n = flat.shape[0]
    pad = (-n) % BLOCK_N
    flat = jnp.pad(flat, (0, pad))
    return xlogx(flat)[:n].reshape(x.shape)


def _entropy(counts):
    """H (nats) over the last axis of unnormalized counts; 0-total -> 0."""
    n = jnp.sum(counts, axis=-1)
    sx = jnp.sum(_xlogx_nd(counts), axis=-1)
    safe_n = jnp.where(n > 0, n, 1.0)
    return jnp.where(n > 0, jnp.log(safe_n) - sx / safe_n, 0.0)


def segsum_model(ids, counts, num_segments):
    """Projection aggregation: out[k] = sum counts[ids == k]."""
    return (segsum(ids, counts, num_segments),)


def pivot_model(star, t, scale):
    """ct_F counts = max(star * scale - t, 0) on aligned rows."""
    return (pivot(star, t, scale),)


def su_model(joint):
    """Symmetric uncertainty of batched joints [B, V, V] -> [B]."""
    hx = _entropy(jnp.sum(joint, axis=2))
    hy = _entropy(jnp.sum(joint, axis=1))
    hxy = _entropy(joint.reshape(joint.shape[0], -1))
    denom = hx + hy
    safe = jnp.where(denom > 0, denom, 1.0)
    mi = jnp.maximum(hx + hy - hxy, 0.0)
    return (jnp.where(denom > 0, 2.0 * mi / safe, 0.0),)


def bnscore_model(counts):
    """Relational pseudo log-likelihood of batched families [B, P, C] -> [B].

    L[b] = sum_pc n_pc (log n_pc - log n_p) / N_b   (Schulte 2011 frequency
    normalization; empty families score 0).
    """
    n_pc = _xlogx_nd(counts).sum(axis=(1, 2))
    n_p = _xlogx_nd(counts.sum(axis=2)).sum(axis=1)
    total = counts.sum(axis=(1, 2))
    safe = jnp.where(total > 0, total, 1.0)
    return (jnp.where(total > 0, (n_pc - n_p) / safe, 0.0),)


def lift_model(body, head, joint, total):
    """Association-rule metrics -> (support, confidence, lift), each [B]."""
    safe_total = jnp.where(total > 0, total, 1.0)
    safe_body = jnp.where(body > 0, body, 1.0)
    safe_head = jnp.where(head > 0, head, 1.0)
    support = jnp.where(total > 0, joint / safe_total, 0.0)
    confidence = jnp.where(body > 0, joint / safe_body, 0.0)
    lift = jnp.where(
        (body > 0) & (head > 0) & (total > 0),
        (joint * safe_total) / (safe_body * safe_head),
        0.0,
    )
    return (support, confidence, lift)
