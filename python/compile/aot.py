"""AOT export: lower the L2 graphs to HLO *text* artifacts for the rust
PJRT runtime.

HLO text (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids that xla_extension 0.5.1
rejects; the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Each graph is compiled at a ladder of static bucket shapes; the rust
runtime pads batches up to the nearest bucket and keeps one compiled PJRT
executable per artifact. `artifacts/manifest.txt` lists every artifact with
its shape parameters.

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402

# Bucket ladders (see DESIGN.md section 4). Sizes are multiples of the
# kernel block sizes (segsum 1024; pivot/xlogx 2048).
SEGSUM_BUCKETS = [(8192, 1024), (65536, 8192), (524288, 65536)]
PIVOT_BUCKETS = [8192, 65536, 524288]
SU_BUCKETS = [(256, 8), (4096, 8)]
BNSCORE_BUCKETS = [(256, 256, 8), (64, 4096, 8)]
LIFT_BUCKETS = [4096]


def to_hlo_text(fn, *args) -> str:
    lowered = jax.jit(fn).lower(*args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_all():
    """Yield (name, hlo_text, manifest_line) for every artifact."""
    for n, k in SEGSUM_BUCKETS:
        name = f"segsum_n{n}_k{k}"
        fn = lambda ids, counts, _k=k: model.segsum_model(ids, counts, _k)
        text = to_hlo_text(fn, spec((n,), jnp.int32), spec((n,), jnp.float64))
        yield name, text, f"segsum n={n} k={k} {name}.hlo.txt"
    for n in PIVOT_BUCKETS:
        name = f"pivot_n{n}"
        text = to_hlo_text(
            model.pivot_model,
            spec((n,), jnp.float64),
            spec((n,), jnp.float64),
            spec((1,), jnp.float64),
        )
        yield name, text, f"pivot n={n} {name}.hlo.txt"
    for b, v in SU_BUCKETS:
        name = f"su_b{b}_v{v}"
        text = to_hlo_text(model.su_model, spec((b, v, v), jnp.float64))
        yield name, text, f"su b={b} v={v} {name}.hlo.txt"
    for b, p, c in BNSCORE_BUCKETS:
        name = f"bnscore_b{b}_p{p}_c{c}"
        text = to_hlo_text(model.bnscore_model, spec((b, p, c), jnp.float64))
        yield name, text, f"bnscore b={b} p={p} c={c} {name}.hlo.txt"
    for b in LIFT_BUCKETS:
        name = f"lift_b{b}"
        v = spec((b,), jnp.float64)
        text = to_hlo_text(model.lift_model, v, v, v, v)
        yield name, text, f"lift b={b} {name}.hlo.txt"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="compat alias: out-dir inferred from file path")
    args = ap.parse_args()
    out_dir = args.out_dir
    if args.out:
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)
    manifest = []
    for name, text, line in build_all():
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest.append(line)
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {out_dir}/manifest.txt ({len(manifest)} artifacts)")


if __name__ == "__main__":
    main()
