"""L1 Pallas kernel: elementwise x*log(x) (0 log 0 = 0).

The entropy/log-likelihood scores of the statistical applications (CFS
symmetric uncertainty, BN pseudo log-likelihood) reduce sums of x*log(x)
terms over contingency-table counts; this kernel is the shared elementwise
hot-spot they call through the L2 graphs in `compile.model`.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 2048


def _xlogx_kernel(x_ref, o_ref):
    x = x_ref[...]
    o_ref[...] = jnp.where(x > 0, x * jnp.log(jnp.where(x > 0, x, 1.0)), 0.0)


@jax.jit
def xlogx(x):
    """Elementwise x*log(x); `x.shape[0]` must be a multiple of BLOCK_N."""
    n = x.shape[0]
    assert n % BLOCK_N == 0, f"n={n} must be a multiple of {BLOCK_N}"
    return pl.pallas_call(
        _xlogx_kernel,
        grid=(n // BLOCK_N,),
        in_specs=[pl.BlockSpec((BLOCK_N,), lambda i: (i,))],
        out_specs=pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), x.dtype),
        interpret=True,
    )(x)
