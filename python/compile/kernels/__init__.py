"""L1 Pallas kernels for the Mobius Join / statistics pipeline.

Modules: `segsum` (GROUP-BY aggregation), `pivot` (Equation-1 fused
arithmetic), `xlogx` (entropy/log-likelihood terms), `ref` (pure-jnp
oracles used by pytest).
"""
