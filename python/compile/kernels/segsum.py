"""L1 Pallas kernel: segment sum (the GROUP-BY aggregation hot-spot).

ct-algebra projection (paper section 4.1.1) is `SELECT SUM(count) GROUP BY
V1..Vk`; once the coordinator has mapped each row's group key to a dense
segment id, the remaining bulk arithmetic is a segment sum, which is what
this kernel computes:

    out[k] = sum_i counts[i] * [ids[i] == k]

Hardware adaptation (DESIGN.md section 3): the paper ran on MySQL/CPU, so
there is no GPU kernel to port. On a real TPU the natural formulation is a
block one-hot matmul feeding the MXU (`counts_block @ onehot(ids_block)`,
bf16/f32); on the CPU PJRT plugin used here that materializes huge
intermediates, so the compiled body uses an in-VMEM scatter-add per block
instead. Both bodies share the same BlockSpec schedule: ids/counts stream
through VMEM in `BLOCK_N` tiles while the `K`-sized accumulator stays
resident (K*8 bytes <= 1 MiB for every bucket in the ladder).

Padding convention: callers pad `ids` with `num_segments` (out of range) so
padding lanes drop out of the scatter.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 1024


def _segsum_kernel_scatter(ids_ref, counts_ref, o_ref):
    """CPU-friendly body: block scatter-add into the resident accumulator."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    ids = ids_ref[...]
    counts = counts_ref[...]
    o_ref[...] += jnp.zeros_like(o_ref).at[ids].add(counts, mode="drop")


def _segsum_kernel_mxu(ids_ref, counts_ref, o_ref):
    """TPU body: one-hot matmul onto the MXU. Compile-only on this image
    (real-TPU lowering emits a Mosaic custom call the CPU plugin cannot
    run); validated through the interpret path in tests."""
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    ids = ids_ref[...]
    counts = counts_ref[...]
    k = o_ref.shape[0]
    onehot = (ids[:, None] == jax.lax.broadcasted_iota(jnp.int32, (ids.shape[0], k), 1)).astype(
        counts.dtype
    )
    o_ref[...] += jnp.dot(counts, onehot, preferred_element_type=counts.dtype)


@functools.partial(jax.jit, static_argnames=("num_segments", "body"))
def segsum(ids, counts, num_segments, body="scatter"):
    """Segment-sum of `counts` by `ids` into `num_segments` bins.

    `ids.shape[0]` must be a multiple of BLOCK_N (callers pad; padding ids
    = num_segments).
    """
    n = ids.shape[0]
    assert n % BLOCK_N == 0, f"n={n} must be a multiple of {BLOCK_N}"
    kernel = _segsum_kernel_scatter if body == "scatter" else _segsum_kernel_mxu
    return pl.pallas_call(
        kernel,
        grid=(n // BLOCK_N,),
        in_specs=[
            pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
            pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((num_segments,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((num_segments,), counts.dtype),
        interpret=True,
    )(ids, counts)
