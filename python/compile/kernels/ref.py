"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

Every kernel in this package must match its reference here exactly (f64
integer counts are exact up to 2**53, so ``assert_allclose(..., rtol=0)`` is
the contract for count arithmetic; entropy terms use tight float tolerances).
"""

import jax.numpy as jnp


def segsum_ref(ids, counts, num_segments):
    """Segment sum: out[k] = sum of counts[i] where ids[i] == k.

    Out-of-range ids (>= num_segments) are dropped -- the runtime uses
    id == num_segments as the padding convention.
    """
    return jnp.zeros((num_segments,), counts.dtype).at[ids].add(
        jnp.where(ids < num_segments, counts, 0), mode="drop"
    )


def pivot_ref(star, t, scale):
    """Fused pivot arithmetic: f = max(star * scale - t, 0).

    Implements the count side of Equation (1): ct_F = ct_* x |X| - ct_T on
    row-aligned vectors (alignment is the caller's job). The clamp only
    guards padding lanes; on real rows star*scale >= t by Proposition 1.
    """
    return jnp.maximum(star * scale - t, 0.0)


def xlogx_ref(x):
    """Elementwise x*log(x) with the 0 log 0 = 0 convention (entropy)."""
    return jnp.where(x > 0, x * jnp.log(jnp.where(x > 0, x, 1.0)), 0.0)


def entropy_ref(counts):
    """Shannon entropy (nats) of an unnormalized count vector.

    H = log(N) - sum(x log x)/N over the last axis; zero-total slices -> 0.
    """
    n = jnp.sum(counts, axis=-1)
    sx = jnp.sum(xlogx_ref(counts), axis=-1)
    safe_n = jnp.where(n > 0, n, 1.0)
    return jnp.where(n > 0, jnp.log(safe_n) - sx / safe_n, 0.0)


def su_ref(joint):
    """Symmetric uncertainty of batched joint count matrices [B, V1, V2].

    SU(X,Y) = 2 * (H(X) + H(Y) - H(X,Y)) / (H(X) + H(Y)); 0 when both
    marginal entropies vanish (constant variables).
    """
    hx = entropy_ref(jnp.sum(joint, axis=2))
    hy = entropy_ref(jnp.sum(joint, axis=1))
    hxy = entropy_ref(joint.reshape(joint.shape[0], -1))
    denom = hx + hy
    safe = jnp.where(denom > 0, denom, 1.0)
    mi = jnp.maximum(hx + hy - hxy, 0.0)
    return jnp.where(denom > 0, 2.0 * mi / safe, 0.0)


def bn_family_ref(counts):
    """Relational pseudo log-likelihood of batched BN families [B, P, C].

    counts[b, p, c] = sufficient statistic for (parent-config p, child
    value c). Per Schulte (2011) the score normalizes by the total count so
    scores are comparable across nodes:

        L = sum_pc n_pc * (log n_pc - log n_p) / N
    """
    n_pc = xlogx_ref(counts).sum(axis=(1, 2))
    n_p = xlogx_ref(counts.sum(axis=2)).sum(axis=1)
    total = counts.sum(axis=(1, 2))
    safe = jnp.where(total > 0, total, 1.0)
    return jnp.where(total > 0, (n_pc - n_p) / safe, 0.0)


def lift_ref(body, head, joint, total):
    """Association-rule metrics over batched count vectors.

    Returns (support, confidence, lift): support = joint/total,
    confidence = joint/body, lift = confidence / (head/total).
    Zero denominators yield 0.
    """
    safe_total = jnp.where(total > 0, total, 1.0)
    safe_body = jnp.where(body > 0, body, 1.0)
    safe_head = jnp.where(head > 0, head, 1.0)
    support = jnp.where(total > 0, joint / safe_total, 0.0)
    confidence = jnp.where(body > 0, joint / safe_body, 0.0)
    lift = jnp.where(
        (body > 0) & (head > 0) & (total > 0),
        (joint * safe_total) / (safe_body * safe_head),
        0.0,
    )
    return support, confidence, lift
