"""L1 Pallas kernel: fused pivot arithmetic (Equation 1 / Algorithm 1).

After the coordinator row-aligns ct_* against pi_Vars(ct_T), the count side
of  ct_F = ct_* x |X1| x ... x |Xl| - ct_T  is a fused elementwise op over
the aligned count vectors:

    f[i] = max(star[i] * scale - t[i], 0)

The max() only guards padding lanes — Proposition 1 guarantees
star*scale >= t on real rows (asserted by the rust runtime in debug mode).
Blocks stream through VMEM in BLOCK_N tiles; `scale` rides along as a
single-element block (scalar operand).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 2048


def _pivot_kernel(star_ref, t_ref, scale_ref, o_ref):
    o_ref[...] = jnp.maximum(star_ref[...] * scale_ref[0] - t_ref[...], 0.0)


@jax.jit
def pivot(star, t, scale):
    """Fused `max(star * scale - t, 0)`; `star.shape[0]` must be a multiple
    of BLOCK_N. `scale` is a shape-(1,) array."""
    n = star.shape[0]
    assert n % BLOCK_N == 0, f"n={n} must be a multiple of {BLOCK_N}"
    return pl.pallas_call(
        _pivot_kernel,
        grid=(n // BLOCK_N,),
        in_specs=[
            pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
            pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_N,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), star.dtype),
        interpret=True,
    )(star, t, scale)


@functools.partial(jax.jit, static_argnames=())
def _unused():  # pragma: no cover - placeholder keeping functools import honest
    return None
