//! Table 5: CFS feature selection for each dataset's target variable, link
//! analysis off vs on, with the Distinctness (1 − Jaccard) comparison and
//! the count of selected relationship features (Rvars).

use mrss::apps::cfs;
use mrss::datagen;
use mrss::mobius::MobiusJoin;
use mrss::schema::RandomVar;
use mrss::util::table::TextTable;

fn scale_for(name: &str) -> f64 {
    if let Ok(s) = std::env::var("MRSS_BENCH_SCALE") {
        return s.parse().expect("MRSS_BENCH_SCALE");
    }
    match name {
        "imdb" => 0.1,
        "movielens" => 0.3,
        _ => 1.0,
    }
}

fn main() {
    println!("=== Table 5: selected features, link analysis off vs on ===\n");
    let mut t = TextTable::new(vec![
        "Dataset", "Target", "#Off", "#On", "Rvars", "Distinctness",
    ]);
    for b in datagen::BENCHMARKS {
        let db = match datagen::generate(b.name, scale_for(b.name), 7) {
            Ok(db) => db,
            Err(e) => {
                eprintln!("{}: {e:#}", b.name);
                continue;
            }
        };
        let schema = &db.schema;
        let res = MobiusJoin::new(&db).run();
        let joint = res.joint_ct();
        let target = schema.var_by_name(b.target).expect("target");
        let attrs: Vec<usize> = (0..schema.random_vars.len())
            .filter(|&v| !matches!(schema.random_vars[v], RandomVar::RelInd { .. }))
            .collect();
        let all: Vec<usize> = (0..schema.random_vars.len()).collect();
        let off_ct = res.link_off();
        let off = cfs::cfs_select(&off_ct, target, &attrs, None);
        let on = cfs::cfs_select(joint, target, &all, None);
        let rvars = on
            .selected
            .iter()
            .filter(|&&v| matches!(schema.random_vars[v], RandomVar::RelInd { .. }))
            .count();
        t.row(vec![
            b.name.to_string(),
            b.target.to_string(),
            if off_ct.is_empty() { "EmptyCT".into() } else { off.selected.len().to_string() },
            on.selected.len().to_string(),
            rvars.to_string(),
            format!("{:.2}", cfs::distinctness(&off.selected, &on.selected)),
        ]);
    }
    print!("{}", t.render());
    println!("\nshape check (paper): distinctness > 0 on the complex schemas — negative-");
    println!("relationship statistics change which features look relevant; Mondial's");
    println!("link-off ct is empty.");
}
