//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. **engine**: native rust ct-algebra vs AOT-XLA offload (segsum/pivot
//!    kernels via PJRT) — same results bit-identical, different cost;
//! 2. **parallel coordinator**: worker pool 1 vs N over the suite (on the
//!    single-core paper testbed N≈1 is expected to win);
//! 3. **chain-depth cap** (paper §8): full lattice vs max_chain_len = 1, 2.

use mrss::coordinator::{run_suite, PoolConfig, SuiteJob};
use mrss::datagen;
use mrss::mobius::MobiusJoin;
use mrss::runtime::{XlaEngine, XlaRuntime};
use mrss::util::format_duration;
use mrss::util::table::TextTable;
use std::time::Instant;

fn main() {
    let scale: f64 =
        std::env::var("MRSS_BENCH_SCALE").ok().and_then(|s| s.parse().ok()).unwrap_or(0.5);

    // --- 1. engine ablation ---
    println!("=== ablation 1: native vs XLA engine (financial @ scale {scale}) ===");
    let db = datagen::generate("financial", scale, 7).unwrap();
    let t0 = Instant::now();
    let native = MobiusJoin::new(&db).run();
    let native_t = t0.elapsed();
    println!("  native: {} ({} stats)", format_duration(native_t), native.num_statistics());
    match XlaRuntime::load_default() {
        Ok(rt) => {
            let engine = XlaEngine::new(&rt);
            let t0 = Instant::now();
            let xla = MobiusJoin::with_engine(&db, &engine).run();
            let xla_t = t0.elapsed();
            assert_eq!(native.joint_ct(), xla.joint_ct(), "engines must agree bit-for-bit");
            println!(
                "  xla   : {} (bit-identical joint; {:.2}x native)",
                format_duration(xla_t),
                xla_t.as_secs_f64() / native_t.as_secs_f64()
            );
        }
        Err(e) => println!("  xla   : skipped ({e})"),
    }

    // --- 2. coordinator worker-pool ablation ---
    println!("\n=== ablation 2: worker pool over the suite (scale {}) ===", scale * 0.2);
    for workers in [1usize, 2, 4] {
        let jobs: Vec<SuiteJob> = datagen::BENCHMARKS
            .iter()
            .map(|b| SuiteJob::new(b.name, scale * 0.2, 7))
            .collect();
        let t0 = Instant::now();
        let reports = run_suite(jobs, PoolConfig { workers, queue_depth: 2 });
        let ok = reports.iter().filter(|r| r.is_ok()).count();
        println!("  workers={workers}: {} ({} jobs ok)", format_duration(t0.elapsed()), ok);
    }

    // --- 3. chain-depth cap (paper §8) ---
    println!("\n=== ablation 3: lattice depth cap (hepatitis @ scale {scale}) ===");
    let db = datagen::generate("hepatitis", scale, 7).unwrap();
    let mut t = TextTable::new(vec!["max_chain_len", "time", "#tables", "#ct_ops"]);
    for cap in [1usize, 2, 3] {
        let t0 = Instant::now();
        let res = MobiusJoin::new(&db).max_chain_len(cap).run();
        t.row(vec![
            cap.to_string(),
            format_duration(t0.elapsed()),
            res.tables.len().to_string(),
            res.metrics.total_ct_ops().to_string(),
        ]);
    }
    print!("{}", t.render());
    println!("\n(capping the chain length trades statistics coverage for time — §8)");
}
