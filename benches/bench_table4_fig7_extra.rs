//! Table 4 + Figure 7: statistics and time for link analysis ON vs OFF.
//!
//! Table 4 columns: #stats link on, link off, #extra statistics, extra
//! time. Figure 7: extra time vs #extra statistics is near-linear — we
//! print the series and the least-squares fit R^2 (the paper's visual
//! claim, quantified).

use mrss::coordinator::{run_job, SuiteJob};
use mrss::util::format_duration;
use mrss::util::table::{commas, TextTable};

fn scale_for(name: &str) -> f64 {
    if let Ok(s) = std::env::var("MRSS_BENCH_SCALE") {
        return s.parse().expect("MRSS_BENCH_SCALE");
    }
    match name {
        "imdb" => 0.2,
        _ => 1.0,
    }
}

fn main() {
    println!("=== Table 4: link analysis on vs off ===\n");
    let mut t = TextTable::new(vec![
        "Dataset", "Link On", "Link Off", "#extra stats", "extra time",
    ]);
    let mut series: Vec<(String, f64, f64)> = Vec::new();
    for b in mrss::datagen::BENCHMARKS {
        let r = match run_job(&SuiteJob::new(b.name, scale_for(b.name), 7)) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("{}: {e:#}", b.name);
                continue;
            }
        };
        t.row(vec![
            b.name.to_string(),
            commas(r.statistics as u128),
            commas(r.link_off_statistics as u128),
            commas(r.extra_statistics as u128),
            format_duration(r.extra_time),
        ]);
        series.push((b.name.to_string(), r.extra_statistics as f64, r.extra_time.as_secs_f64()));
    }
    print!("{}", t.render());

    println!("\n=== Figure 7: extra time (s) vs #extra statistics ===");
    for (name, x, y) in &series {
        println!("  {name:<12} x={x:>12.0}  y={y:>9.3}s");
    }
    let n = series.len() as f64;
    let (sx, sy): (f64, f64) =
        series.iter().fold((0.0, 0.0), |(a, b), (_, x, y)| (a + x, b + y));
    let (mx, my) = (sx / n, sy / n);
    let (mut sxx, mut sxy, mut syy) = (0.0, 0.0, 0.0);
    for (_, x, y) in &series {
        sxx += (x - mx) * (x - mx);
        sxy += (x - mx) * (y - my);
        syy += (y - my) * (y - my);
    }
    let r2 = if sxx > 0.0 && syy > 0.0 { sxy * sxy / (sxx * syy) } else { 1.0 };
    let slope_us = if sxx > 0.0 { sxy / sxx * 1e6 } else { 0.0 };
    println!("\nlinear fit: {slope_us:.3} us per extra statistic, R^2 = {r2:.3}");
    println!("(paper: near-linear relationship confirming the O(r log r) analysis)");
}
