//! Figure 8: breakdown of Möbius Join running time — Pivot (Algorithm 1)
//! vs main loop (Algorithm 2), and per-ct-algebra-operator attribution
//! (the paper observes subtraction/union dominate cross product).

use mrss::coordinator::{run_job, SuiteJob};
use mrss::mobius::metrics::{CtOp, ALL_OPS};
use mrss::util::table::TextTable;



fn scale_for(name: &str) -> f64 {
    if let Ok(s) = std::env::var("MRSS_BENCH_SCALE") {
        return s.parse().expect("MRSS_BENCH_SCALE");
    }
    match name {
        "imdb" => 0.2,
        _ => 1.0,
    }
}

fn main() {
    println!("=== Figure 8: MJ running-time breakdown ===\n");
    let mut t = TextTable::new(vec![
        "Dataset", "total(s)", "positive%", "pivot%", "mainloop%", "sub+union%", "cross%", "#ct_ops",
    ]);
    for b in mrss::datagen::BENCHMARKS {
        let r = match run_job(&SuiteJob::new(b.name, scale_for(b.name), 7)) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("{}: {e:#}", b.name);
                continue;
            }
        };
        let m = &r.metrics;
        let tot = m.total.as_secs_f64().max(1e-9);
        let pct = |d: std::time::Duration| format!("{:.1}", 100.0 * d.as_secs_f64() / tot);
        let sub_union = m.op_time(CtOp::Subtract) + m.op_time(CtOp::Union)
            + m.op_time(CtOp::Project) + m.op_time(CtOp::Extend);
        t.row(vec![
            b.name.to_string(),
            format!("{tot:.2}"),
            pct(m.positive),
            pct(m.pivot),
            pct(m.main_loop),
            pct(sub_union),
            pct(m.op_time(CtOp::Cross)),
            m.total_ct_ops().to_string(),
        ]);
    }
    print!("{}", t.render());

    println!("\nper-operator detail (largest dataset in the run):");
    if let Ok(r) = run_job(&SuiteJob::new("financial", scale_for("financial"), 7)) {
        for op in ALL_OPS {
            println!(
                "  {:<10} x{:<5} {}",
                op.name(),
                r.metrics.op_count(op),
                mrss::util::format_duration(r.metrics.op_time(op))
            );
        }
    }
    println!("\nshape check (paper): Pivot-side ops (subtract/union/project/extend)");
    println!("dominate cross product; most MJ time is spent outside the positive joins");
    println!("on the dense-statistics schemas.");
}
