//! Table 3: Möbius Join vs Cross Product — time, CP size, #statistics,
//! compression ratio, with the paper's "N.T." behaviour for infeasible CP.
//!
//! Run: `cargo bench --bench bench_table3_mj_vs_cp`
//! Scale: env `MRSS_BENCH_SCALE` (default per-dataset, IMDB reduced for the
//! single-core testbed; EXPERIMENTS.md records a full-scale run).

use mrss::baseline::CpBudget;
use mrss::coordinator::{run_job, SuiteJob};
use mrss::util::format_duration;
use mrss::util::table::{commas, TextTable};
use std::time::Duration;

fn scale_for(name: &str) -> f64 {
    if let Ok(s) = std::env::var("MRSS_BENCH_SCALE") {
        return s.parse().expect("MRSS_BENCH_SCALE");
    }
    match name {
        "imdb" => 0.2,
        _ => 1.0,
    }
}

fn main() {
    println!("=== Table 3: contingency-table construction, MJ vs CP ===");
    println!("paper reference at scale 1.0: MovieLens 2.70s/704s, Mutagenesis 1.67s/1096s,");
    println!("Financial 1421s/N.T., Hepatitis 3536s/N.T., IMDB 7467s/N.T.,");
    println!("Mondial 1112s/132s, UW-CSE 3.84s/350s (MJ/CP, MySQL testbed)\n");

    let mut t = TextTable::new(vec![
        "Dataset", "scale", "MJ-time", "CP-time", "CP-#tuples", "#Statistics", "Compress",
    ]);
    for b in mrss::datagen::BENCHMARKS {
        let scale = scale_for(b.name);
        let job = SuiteJob::new(b.name, scale, 7).with_cp(CpBudget {
            max_time: Duration::from_secs(
                std::env::var("MRSS_CP_BUDGET").ok().and_then(|s| s.parse().ok()).unwrap_or(90),
            ),
            max_tuples: 300_000_000,
        });
        let r = match run_job(&job) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("{}: {e:#}", b.name);
                continue;
            }
        };
        let cp = r.cp.as_ref().unwrap();
        t.row(vec![
            b.name.to_string(),
            format!("{scale}"),
            format_duration(r.mj_time),
            if cp.non_termination { "N.T.".into() } else { format_duration(cp.elapsed) },
            commas(cp.cp_tuples),
            commas(r.statistics as u128),
            match r.compression_ratio() {
                Some(c) => format!("{c:.2}"),
                None => "-".into(),
            },
        ]);
    }
    print!("{}", t.render());
    println!("\nshape checks: MJ << CP except low-compression Mondial; CP N.T. on the");
    println!("three complex schemas; compression spans orders of magnitude.");
}
