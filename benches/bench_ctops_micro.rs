//! Micro-benchmarks of the ct-algebra operators (the unit costs behind the
//! §4.1.3 cost model): every packed-key operator is measured against the
//! retained row-major reference implementation (`mrss::ct::reference`) on
//! identical inputs, asserting bit-identical results as it goes — at both
//! packed tiers:
//!
//! * `packed64` — 8 columns x 2 bits (16-bit layouts, one-word keys);
//! * `packed128` — 24 columns x 3 bits (72-bit layouts, two-word keys, the
//!   hepatitis/imdb joint-table regime that used to run row-major).
//!
//! Output: a human-readable table on stdout, then a JSON record (printed to
//! stdout, or written to the path in `MRSS_BENCH_JSON` when set) in the
//! shape of `BENCH_ctops_micro.json` at the repo root — refresh that file
//! with:
//!
//! ```text
//! MRSS_BENCH_ASSERT=1 MRSS_BENCH_JSON=BENCH_ctops_micro.json \
//!     cargo bench --bench bench_ctops_micro
//! ```

use mrss::ct::reference::RefTable;
use mrss::ct::CtTable;
use mrss::util::timer::bench_median;
use mrss::util::{format_duration, Pcg64};
use std::time::Duration;

fn random_ct(rng: &mut Pcg64, n: usize, width: usize, arity: u16) -> CtTable {
    let vars: Vec<usize> = (0..width).collect();
    let mut rows = Vec::with_capacity(n * width);
    let mut counts = Vec::with_capacity(n);
    for _ in 0..n {
        for _ in 0..width {
            rows.push(rng.below(arity as u64) as u16);
        }
        counts.push(rng.below(50) + 1);
    }
    // Pin every column's observed cap so the layout width (and therefore
    // the storage tier) does not depend on the draw.
    rows.extend(std::iter::repeat(arity - 1).take(width));
    counts.push(1);
    CtTable::from_raw(vars, rows, counts)
}

struct Sample {
    tier: &'static str,
    rows: usize,
    op: &'static str,
    packed: Duration,
    rowmajor: Duration,
}

fn record(
    out: &mut Vec<Sample>,
    tier: &'static str,
    rows: usize,
    op: &'static str,
    packed: Duration,
    rowmajor: Duration,
) {
    let speedup = rowmajor.as_secs_f64() / packed.as_secs_f64().max(1e-12);
    println!(
        "  {op:<18} packed {:>10}   row-major {:>10}   {speedup:>5.2}x",
        format_duration(packed),
        format_duration(rowmajor),
    );
    out.push(Sample { tier, rows, op, packed, rowmajor });
}

/// Measure every operator on one (size, width, arity) configuration whose
/// tables are expected on storage tier `tier`.
#[allow(clippy::too_many_arguments)]
fn bench_config(
    rng: &mut Pcg64,
    samples: &mut Vec<Sample>,
    iters: usize,
    tier: &'static str,
    n: usize,
    width: usize,
    arity: u16,
) {
    let a = random_ct(rng, n, width, arity);
    let b = random_ct(rng, n, width, arity);
    assert_eq!(a.tier(), tier, "config expected tier {tier}");
    let (ra, rb) = (RefTable::from(&a), RefTable::from(&b));
    let rows = a.len();
    println!("-- [{tier}] ct with {rows} rows (requested {n}), width {width} --");

    // Correctness cross-checks before timing anything.
    assert_eq!(a.project(&[0, 1, 2]), ra.project(&[0, 1, 2]).to_ct());
    assert_eq!(a.add(&b), ra.add(&rb).to_ct());
    assert_eq!(a.select(&[(0, 1)]), ra.select(&[(0, 1)]).to_ct());
    assert_eq!(a.condition(&[(0, 1)]), ra.condition(&[(0, 1)]).to_ct());

    let p = bench_median(iters, || a.project(&[0, 1, 2]));
    let r = bench_median(iters, || ra.project(&[0, 1, 2]));
    record(samples, tier, rows, "project/3cols", p, r);

    let p = bench_median(iters, || a.add(&b));
    let r = bench_median(iters, || ra.add(&rb));
    record(samples, tier, rows, "add", p, r);

    let sum = a.add(&b);
    let rsum = ra.add(&rb);
    assert_eq!(sum.subtract(&b).unwrap(), rsum.subtract(&rb).unwrap().to_ct());
    let p = bench_median(iters, || sum.subtract(&b).unwrap());
    let r = bench_median(iters, || rsum.subtract(&rb).unwrap());
    record(samples, tier, rows, "subtract", p, r);

    let p = bench_median(iters, || a.select(&[(0, 1)]));
    let r = bench_median(iters, || ra.select(&[(0, 1)]));
    record(samples, tier, rows, "select", p, r);

    let p = bench_median(iters, || a.condition(&[(0, 1)]));
    let r = bench_median(iters, || ra.condition(&[(0, 1)]));
    record(samples, tier, rows, "condition", p, r);

    let p = bench_median(iters, || a.extend_const(&[(50, 1), (51, 0)]));
    let r = bench_median(iters, || ra.extend_const(&[(50, 1), (51, 0)]));
    record(samples, tier, rows, "extend_const", p, r);

    // Cross stays on small operands (its output is quadratic). For the
    // two-word config the merged layout still exceeds 64 bits, so the
    // kernel under test is the u128 monomorphization.
    let small = random_ct(rng, 64, 2, 3);
    let small2 = {
        let mut s = RefTable::from(&small);
        s.vars = vec![100, 101];
        s.to_ct()
    };
    let (rsmall, rsmall2) = (RefTable::from(&small), RefTable::from(&small2));
    assert_eq!(small.cross(&small2), rsmall.cross(&rsmall2).to_ct());
    if tier == "packed64" {
        let p = bench_median(iters, || small.cross(&small2));
        let r = bench_median(iters, || rsmall.cross(&rsmall2));
        record(samples, tier, rows, "cross(64x64)", p, r);
    } else {
        let wide_small = {
            let mut t = random_ct(rng, 64, width, arity);
            // Disjoint var ids for crossing against `small`.
            t.vars = t.vars.iter().map(|v| v + 200).collect();
            t
        };
        assert!(wide_small.cross(&small).is_packed2());
        let rwide = RefTable::from(&wide_small);
        assert_eq!(wide_small.cross(&small), rwide.cross(&rsmall).to_ct());
        let p = bench_median(iters, || wide_small.cross(&small));
        let r = bench_median(iters, || rwide.cross(&rsmall));
        record(samples, tier, rows, "cross(widex64)", p, r);
    }
    println!();
}

fn main() {
    let mut rng = Pcg64::seeded(42);
    let iters = 9;
    let mut samples: Vec<Sample> = Vec::new();
    // Arm the kernel hot-spot timers for the whole run: the bench is the
    // one place the per-(operator, tier) tick totals are interesting on
    // their own, so they ride the JSON artifact next to the medians.
    mrss::ct::ticks::set_enabled(true);
    println!("=== ct-algebra: packed keys vs row-major reference (median of {iters}) ===\n");
    for &n in &[10_000usize, 100_000, 400_000] {
        bench_config(&mut rng, &mut samples, iters, "packed64", n, 8, 4);
    }
    // The two-word tier: 24 columns x 3 bits = 72-bit layouts. Before this
    // tier existed, these tables ran every operator on the row-major path.
    for &n in &[10_000usize, 100_000] {
        bench_config(&mut rng, &mut samples, iters, "packed128", n, 24, 6);
    }

    let json = render_json(&samples, iters);
    match std::env::var("MRSS_BENCH_JSON") {
        Ok(path) if !path.is_empty() => {
            std::fs::write(&path, &json).expect("writing bench json");
            println!("wrote {path}");
        }
        _ => println!("{json}"),
    }

    // The point of the packed-key refactor: the hot operators must beat the
    // row-major baseline at the largest size of each tier. Opt-in
    // (MRSS_BENCH_ASSERT=1); CI runs with the assertion on, so the margin
    // below absorbs shared-runner timing jitter — a genuine regression
    // (a packed kernel degrading to row-major-or-worse work) overshoots a
    // 15% band by multiples, while median-of-9 noise stays within it.
    if std::env::var("MRSS_BENCH_ASSERT").as_deref() == Ok("1") {
        const NOISE_MARGIN: f64 = 1.15;
        for (tier, cross_op) in [("packed64", "cross(64x64)"), ("packed128", "cross(widex64)")] {
            for op in ["project/3cols", "subtract", cross_op] {
                let worst = samples
                    .iter()
                    .filter(|s| s.tier == tier && s.op == op)
                    .max_by_key(|s| s.rows)
                    .expect("sample missing");
                assert!(
                    worst.packed.as_secs_f64() <= worst.rowmajor.as_secs_f64() * NOISE_MARGIN,
                    "[{tier}] {op}: packed {a:?} slower than row-major {b:?}",
                    a = worst.packed,
                    b = worst.rowmajor,
                );
            }
        }
        println!("packed >= row-major (within noise) on all headline ops, both tiers: OK");
    }
}

fn render_json(samples: &[Sample], iters: usize) -> String {
    let mut s = String::from("{\n");
    s.push_str("  \"bench\": \"ctops_micro\",\n");
    s.push_str("  \"unit\": \"nanoseconds (median)\",\n");
    s.push_str(&format!("  \"iters\": {iters},\n"));
    s.push_str("  \"results\": [\n");
    for (i, sm) in samples.iter().enumerate() {
        let speedup = sm.rowmajor.as_secs_f64() / sm.packed.as_secs_f64().max(1e-12);
        s.push_str(&format!(
            "    {{\"tier\": \"{}\", \"rows\": {}, \"op\": \"{}\", \"packed_ns\": {}, \"rowmajor_ns\": {}, \"speedup\": {:.2}}}{}\n",
            sm.tier,
            sm.rows,
            sm.op,
            sm.packed.as_nanos(),
            sm.rowmajor.as_nanos(),
            speedup,
            if i + 1 == samples.len() { "" } else { "," },
        ));
    }
    s.push_str("  ],\n");
    // The hot-spot timer totals accumulated across the whole run (packed
    // kernels only — the row-major reference is untimed by design).
    let ticks: Vec<_> =
        mrss::ct::ticks::snapshot().into_iter().filter(|&(_, _, c, _)| c > 0).collect();
    s.push_str("  \"kernel_ticks\": [\n");
    for (i, (kernel, tier, count, ns)) in ticks.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"kernel\": \"{kernel}\", \"tier\": \"{tier}\", \"calls\": {count}, \"ns\": {ns}}}{}\n",
            if i + 1 == ticks.len() { "" } else { "," },
        ));
    }
    s.push_str("  ],\n");
    let hottest = match mrss::ct::ticks::hottest() {
        Some((name, _, ns)) => format!("{{\"kernel\": \"{name}\", \"ns\": {ns}}}"),
        None => "null".to_string(),
    };
    s.push_str(&format!("  \"hottest_kernel\": {hottest}\n}}\n"));
    s
}
