//! Micro-benchmarks of the ct-algebra operators (the unit costs behind the
//! §4.1.3 cost model): projection, add/subtract sort-merge, cross product,
//! plus the XLA-offloaded project/subtract for comparison.

use mrss::ct::CtTable;
use mrss::mobius::{CtEngine, NativeEngine};
use mrss::runtime::{XlaEngine, XlaRuntime};
use mrss::util::timer::bench_median;
use mrss::util::Pcg64;

fn random_ct(rng: &mut Pcg64, n: usize, width: usize, arity: u16) -> CtTable {
    let vars: Vec<usize> = (0..width).collect();
    let mut rows = Vec::with_capacity(n * width);
    let mut counts = Vec::with_capacity(n);
    for _ in 0..n {
        for _ in 0..width {
            rows.push(rng.below(arity as u64) as u16);
        }
        counts.push(rng.below(50) + 1);
    }
    CtTable::from_raw(vars, rows, counts)
}

fn main() {
    let mut rng = Pcg64::seeded(42);
    let iters = 9;
    println!("=== ct-algebra operator micro-benchmarks (median of {iters}) ===\n");
    for &n in &[10_000usize, 100_000, 400_000] {
        let a = random_ct(&mut rng, n, 8, 4);
        let b = random_ct(&mut rng, n, 8, 4);
        let rows = a.len();
        println!("-- ct with {rows} rows (requested {n}), width 8 --");

        let d = bench_median(iters, || a.project(&[0, 1, 2]));
        println!("  project/3cols      {:>10}", mrss::util::format_duration(d));
        let d = bench_median(iters, || a.add(&b));
        println!("  add (sort-merge)   {:>10}", mrss::util::format_duration(d));
        let sum = a.add(&b);
        let d = bench_median(iters, || sum.subtract(&b).unwrap());
        println!("  subtract           {:>10}", mrss::util::format_duration(d));
        let small = random_ct(&mut rng, 64, 2, 3);
        let small2 = {
            let mut s = small.clone();
            s.vars = vec![100, 101];
            s
        };
        let d = bench_median(iters, || small.cross(&small2));
        println!("  cross (64x64)      {:>10}", mrss::util::format_duration(d));
        let d = bench_median(iters, || a.select(&[(0, 1)]));
        println!("  select             {:>10}", mrss::util::format_duration(d));
        let d = bench_median(iters, || a.extend_const(&[(50, 1), (51, 0)]));
        println!("  extend_const       {:>10}", mrss::util::format_duration(d));

        if let Ok(rt) = XlaRuntime::load_default() {
            let e = XlaEngine::new(&rt);
            let ne = NativeEngine;
            assert_eq!(e.project(&a, &[0, 1, 2]), ne.project(&a, &[0, 1, 2]));
            let d = bench_median(iters, || e.project(&a, &[0, 1, 2]));
            println!("  project via XLA    {:>10}", mrss::util::format_duration(d));
            let d = bench_median(iters, || e.subtract(&sum, &b).unwrap());
            println!("  subtract via XLA   {:>10}", mrss::util::format_duration(d));
        }
        println!();
    }
}
