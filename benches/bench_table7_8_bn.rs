//! Tables 7-8: Bayesian-network structure learning with link analysis on
//! vs off — learning time (Table 7) and statistical scores: relational
//! pseudo log-likelihood, #parameters, R2R / A2R edges (Table 8). Both
//! structures are scored on the same link-on joint table.

use mrss::apps::bayesnet;
use mrss::datagen;
use mrss::mobius::MobiusJoin;
use mrss::util::format_duration;
use mrss::util::table::TextTable;

fn scale_for(name: &str) -> f64 {
    if let Ok(s) = std::env::var("MRSS_BENCH_SCALE") {
        return s.parse().expect("MRSS_BENCH_SCALE");
    }
    match name {
        "imdb" => 0.1,
        "movielens" => 0.3,
        _ => 1.0,
    }
}

fn main() {
    println!("=== Tables 7-8: BN structure learning, link analysis on vs off ===\n");
    let mut t = TextTable::new(vec![
        "Dataset", "Mode", "learn-time", "log-lik", "#params", "R2R", "A2R",
    ]);
    for b in datagen::BENCHMARKS {
        let db = match datagen::generate(b.name, scale_for(b.name), 7) {
            Ok(db) => db,
            Err(e) => {
                eprintln!("{}: {e:#}", b.name);
                continue;
            }
        };
        let schema = &db.schema;
        let res = MobiusJoin::new(&db).run();
        let joint = res.joint_ct();
        for link_on in [true, false] {
            // Mondial: link-off ct is empty (paper reports N/A).
            if !link_on && res.link_off().is_empty() {
                t.row(vec![
                    b.name.to_string(),
                    "Off".to_string(),
                    "N/A".to_string(),
                    "N/A".to_string(),
                    "N/A".to_string(),
                    "-".to_string(),
                    "-".to_string(),
                ]);
                continue;
            }
            let out = bayesnet::learn_structure(schema, &res, link_on, Default::default());
            let m = bayesnet::score_structure(schema, &out.bn, joint, None);
            t.row(vec![
                b.name.to_string(),
                if link_on { "On" } else { "Off" }.to_string(),
                format_duration(out.elapsed),
                format!("{:.2}", m.loglik),
                m.params.to_string(),
                m.r2r.to_string(),
                m.a2r.to_string(),
            ]);
        }
    }
    print!("{}", t.render());
    println!("\nshape checks (paper): link-on learning is slower (more information);");
    println!("R2R/A2R > 0 only with link analysis on; on the complex schemas link-on");
    println!("finds better fit (higher log-lik) — cf. Financial and IMDB in Table 8.");
}
