//! Table 6: how many of the top-20 association rules (by lift) use
//! relationship variables, per dataset, with link analysis on.
//! (With link analysis off every relationship variable is constant T and
//! can never appear in a rule — the paper's point.)

use mrss::apps::apriori::{apriori, AprioriConfig};
use mrss::datagen;
use mrss::mobius::MobiusJoin;
use mrss::util::table::TextTable;

fn scale_for(name: &str) -> f64 {
    if let Ok(s) = std::env::var("MRSS_BENCH_SCALE") {
        return s.parse().expect("MRSS_BENCH_SCALE");
    }
    match name {
        "imdb" => 0.1,
        "movielens" => 0.3,
        _ => 1.0,
    }
}

fn main() {
    println!("=== Table 6: top-20 rules using relationship variables ===");
    println!("paper: 14/20 20/20 12/20 15/20 20/20 16/20 12/20\n");
    let mut t = TextTable::new(vec!["Dataset", "#rules w/ relationship vars", "top lift"]);
    for b in datagen::BENCHMARKS {
        let db = match datagen::generate(b.name, scale_for(b.name), 7) {
            Ok(db) => db,
            Err(e) => {
                eprintln!("{}: {e:#}", b.name);
                continue;
            }
        };
        let schema = &db.schema;
        let res = MobiusJoin::new(&db).run();
        let rules = apriori(schema, res.joint_ct(), AprioriConfig::default(), None);
        let with_rel = rules.iter().filter(|r| r.uses_rel_var(schema)).count();
        t.row(vec![
            b.name.to_string(),
            format!("{}/{}", with_rel, rules.len()),
            rules.first().map(|r| format!("{:.2}", r.lift)).unwrap_or("-".into()),
        ]);
    }
    print!("{}", t.render());
    println!("\nshape check (paper): a majority of top rules use relationship variables.");
}
